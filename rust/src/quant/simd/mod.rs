//! Runtime-dispatched SIMD kernel backend for the fused attention and
//! quantization hot paths.
//!
//! The paper's headline result is its *vectorized* kernel; the four
//! scalar [`Variant`]s mirror its loop structures but rely entirely on
//! autovectorization — and the bit-stability contract (serial adds in a
//! pinned order) actively blocks the compiler from using packed sums in
//! the decode score pass. This module adds **explicit** SIMD
//! implementations behind runtime CPU-feature dispatch:
//!
//! * [`KernelBackend`] — the config knob (`auto | scalar | simd`,
//!   `--kernel-backend`, `"kernel_backend"`, `KVQ_KERNEL_BACKEND` env
//!   override for CI), resolved once at engine/cache init into an
//! * [`Isa`] — the concrete instruction set the hot loops run on:
//!   AVX2 on x86_64 (runtime `cpuid` detection), NEON on aarch64
//!   (architecturally mandatory), or the scalar fallback. `simd` on a
//!   host without SIMD degrades to scalar.
//!
//! Every dispatcher here takes the resolved [`Isa`] and falls back to
//! the scalar kernels ([`super::attn`], [`super::quantize`],
//! [`super::dequantize`], [`super::int4`]) — which stay bit-identical to
//! the pre-backend code — so `kernel_backend=scalar` reproduces legacy
//! bytes exactly.
//!
//! **Per-backend bit-stability contract.**
//!
//! * *Encode / decode / softmax·V accumulation are bit-identical across
//!   backends.* The SIMD paths perform the same IEEE-exact operations in
//!   the same per-element order as the scalar kernels (convert, `·s`,
//!   `·w`, `+` — no FMA contraction, division vectorized but IEEE-exact,
//!   integer rounding delegated to the scalar finisher on AVX2 and to
//!   `FRINTA` — ties-away, `f32::round` semantics — on NEON). Stored
//!   cache bytes therefore never depend on the backend.
//! * *The score-pass dot reassociates.* [`dot_rows_i8`] (and the f32 /
//!   int4 twins) accumulate channels in vector lanes, so SIMD scores
//!   differ from scalar within f32 accumulation error — compared against
//!   the f64 reference with a pinned tolerance by `tests/proptests.rs`.
//!   Consequently tokens may differ *between* backends, but **same
//!   backend + same threads ⇒ byte-identical tokens**, and staged vs
//!   paged decode remain bit-identical to each other under any single
//!   backend (per-row dots and row-ascending accumulation are partition
//!   invariant).
//!
//! Dispatch is safe: each arm re-checks [`detect`] (a cached lookup)
//! before entering a `target_feature` function, so a hand-constructed
//! [`Isa`] can never execute unsupported instructions.

#[cfg(target_arch = "x86_64")]
mod avx2;
#[cfg(target_arch = "aarch64")]
mod neon;

use super::attn;
use super::dequantize;
use super::int4;
use super::quantize;
use super::Variant;
use crate::QMAX;
use std::sync::OnceLock;

/// The `kernel_backend` config knob (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelBackend {
    /// Best available ISA on this host (the default).
    Auto,
    /// Force the scalar fallback (bit-identical to the pre-backend code).
    Scalar,
    /// Request SIMD; degrades to scalar when the host has none.
    Simd,
}

impl KernelBackend {
    pub fn parse(s: &str) -> Option<KernelBackend> {
        Some(match s {
            "auto" => KernelBackend::Auto,
            "scalar" => KernelBackend::Scalar,
            "simd" => KernelBackend::Simd,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            KernelBackend::Auto => "auto",
            KernelBackend::Scalar => "scalar",
            KernelBackend::Simd => "simd",
        }
    }

    /// Resolve the knob to a concrete ISA. The `KVQ_KERNEL_BACKEND` env
    /// var overrides the configured value (the CI scalar-fallback job
    /// forces `scalar` this way); an unparseable value is ignored with a
    /// one-time warning so a typo (`Scalar`, `avx2`, …) cannot silently
    /// serve the wrong backend.
    pub fn resolve(self) -> Isa {
        let env = std::env::var("KVQ_KERNEL_BACKEND").ok();
        if let Some(v) = env.as_deref() {
            if KernelBackend::parse(v).is_none() {
                static WARNED: OnceLock<()> = OnceLock::new();
                WARNED.get_or_init(|| {
                    crate::warn!(
                        "ignoring unparseable KVQ_KERNEL_BACKEND={v:?} \
                         (expected auto|scalar|simd); using configured {}",
                        self.name()
                    );
                });
            }
        }
        self.resolve_with(env.as_deref())
    }

    /// [`Self::resolve`] with an explicit env override (testable without
    /// mutating process env, which races across test threads).
    pub fn resolve_with(self, env: Option<&str>) -> Isa {
        let requested = env.and_then(KernelBackend::parse).unwrap_or(self);
        match requested {
            KernelBackend::Scalar => Isa::Scalar,
            KernelBackend::Auto | KernelBackend::Simd => detect(),
        }
    }
}

/// A concrete instruction set the kernels dispatch on. Obtain via
/// [`KernelBackend::resolve`] / [`detect`]; the dispatchers guard every
/// SIMD arm against the detected ISA, so a mismatched value silently
/// falls back to scalar instead of faulting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    Scalar,
    /// x86_64 AVX2 (256-bit; runtime-detected).
    Avx2,
    /// aarch64 NEON/ASIMD (128-bit; mandatory on aarch64).
    Neon,
}

impl Isa {
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }

    pub fn is_simd(self) -> bool {
        self != Isa::Scalar
    }
}

/// Best ISA available on this host (cached after the first call).
pub fn detect() -> Isa {
    static DETECTED: OnceLock<Isa> = OnceLock::new();
    *DETECTED.get_or_init(detect_uncached)
}

fn detect_uncached() -> Isa {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return Isa::Avx2;
        }
    }
    // NEON/ASIMD is architecturally mandatory on aarch64; everything
    // else falls back to the scalar kernels.
    if cfg!(target_arch = "aarch64") {
        Isa::Neon
    } else {
        Isa::Scalar
    }
}

/// The session default: `KernelBackend::Auto` resolved through the env
/// override — what components use when no engine config reaches them
/// (direct cache-manager construction, model-level tests).
pub fn default_isa() -> Isa {
    KernelBackend::Auto.resolve()
}

/// Finish a precomputed quotient `q = val / scale` exactly as
/// [`quantize::quantize_one`] would (the AVX2 encode path vectorizes the
/// division — IEEE-exact, so quotients match the scalar writer bit for
/// bit — and finishes round/clamp here).
#[inline(always)]
#[cfg_attr(not(target_arch = "x86_64"), allow(dead_code))]
pub(crate) fn code_i8(q: f32, scale: f32) -> i8 {
    if scale <= 0.0 {
        return 0;
    }
    let r = q.round();
    if r.is_nan() {
        return 0;
    }
    r.clamp(-QMAX, QMAX) as i8
}

/// INT4 twin of [`code_i8`] (grid bound ±7, [`int4::quantize_one4`]
/// semantics).
#[inline(always)]
#[cfg_attr(not(target_arch = "x86_64"), allow(dead_code))]
pub(crate) fn code_i4(q: f32, scale: f32) -> i8 {
    if scale <= 0.0 {
        return 0;
    }
    let r = q.round();
    if r.is_nan() {
        return 0;
    }
    r.clamp(-int4::Q4MAX, int4::Q4MAX) as i8
}

// ---------------------------------------------------------------------------
// Fused attention dispatchers.
// ---------------------------------------------------------------------------

/// Fused dequant·dot over an INT8 slab through the selected backend.
/// Scalar delegates to the paper-variant kernels ([`attn::dot_rows_i8`]);
/// SIMD has a single access pattern (`variant` only shapes the scalar
/// fallback). SIMD sums reassociate into vector lanes (module docs).
#[inline]
pub fn dot_rows_i8(
    isa: Isa,
    variant: Variant,
    q: &[f32],
    blk: &[i8],
    scales: &[f32],
    out: &mut [f32],
) {
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: guard re-checks the cached detection, so the AVX2 body
        // only ever runs on a host that reported the feature.
        Isa::Avx2 if detect() == Isa::Avx2 => unsafe { avx2::dot_rows_i8(q, blk, scales, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: as above for NEON.
        Isa::Neon if detect() == Isa::Neon => unsafe { neon::dot_rows_i8(q, blk, scales, out) },
        _ => attn::dot_rows_i8(variant, q, blk, scales, out),
    }
}

/// Fused softmax·V accumulation over an INT8 slab. Bit-identical across
/// backends (same per-channel op sequence, rows ascending — module docs).
#[inline]
pub fn accumulate_rows_i8(
    isa: Isa,
    variant: Variant,
    w: &[f32],
    blk: &[i8],
    scales: &[f32],
    acc: &mut [f32],
) {
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: see dot_rows_i8.
        Isa::Avx2 if detect() == Isa::Avx2 => unsafe {
            avx2::accumulate_rows_i8(w, blk, scales, acc)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: see dot_rows_i8.
        Isa::Neon if detect() == Isa::Neon => unsafe {
            neon::accumulate_rows_i8(w, blk, scales, acc)
        },
        _ => attn::accumulate_rows_i8(variant, w, blk, scales, acc),
    }
}

/// FP32 twin of [`dot_rows_i8`] (no scales — nothing to fuse).
#[inline]
pub fn dot_rows_f32(isa: Isa, q: &[f32], blk: &[f32], out: &mut [f32]) {
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: see dot_rows_i8.
        Isa::Avx2 if detect() == Isa::Avx2 => unsafe { avx2::dot_rows_f32(q, blk, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: see dot_rows_i8.
        Isa::Neon if detect() == Isa::Neon => unsafe { neon::dot_rows_f32(q, blk, out) },
        _ => attn::dot_rows_f32(q, blk, out),
    }
}

/// FP32 twin of [`accumulate_rows_i8`]; bit-identical across backends.
#[inline]
pub fn accumulate_rows_f32(isa: Isa, w: &[f32], blk: &[f32], acc: &mut [f32]) {
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: see dot_rows_i8.
        Isa::Avx2 if detect() == Isa::Avx2 => unsafe { avx2::accumulate_rows_f32(w, blk, acc) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: see dot_rows_i8.
        Isa::Neon if detect() == Isa::Neon => unsafe { neon::accumulate_rows_f32(w, blk, acc) },
        _ => attn::accumulate_rows_f32(w, blk, acc),
    }
}

#[inline]
fn ensure_scratch(scratch: &mut Vec<f32>, d: usize) {
    if scratch.len() < d {
        scratch.resize(d, 0.0);
    }
}

// ---------------------------------------------------------------------------
// Fused multi-query dispatchers (batched decode).
// ---------------------------------------------------------------------------

pub use super::attn::MqMember;

/// Fused multi-query dequant·dot over an INT8 slab: one slab read for W
/// queries. **Per-backend bit-identity**: for every member the result is
/// bit-identical to a per-member [`dot_rows_i8`] call on the same `isa`.
///
/// * Scalar delegates to [`attn::dot_rows_i8_mq`] (fans each single-
///   rounded `row·s` product out to every member, contract bits).
/// * AVX2 dequantizes the slab **once** into `scratch` and runs the f32
///   dot per member — bit-identical to the fused AVX2 i8 dot because the
///   two share the exact lane structure and the fused path's internal
///   products are exactly [`dequantize_row_into`]'s outputs.
/// * NEON's i8 and f32 dots group lanes differently, so composition
///   would change bits; the NEON arm instead runs the fused i8 dot per
///   member over the (now L1-hot) slab — bandwidth amortized, the
///   per-member expression untouched.
#[allow(unused_variables)] // rows/scratch idle on arms that don't compose
pub fn dot_rows_i8_mq(
    isa: Isa,
    variant: Variant,
    d: usize,
    q_arena: &[f32],
    blk: &[i8],
    scales: &[f32],
    members: &[MqMember],
    scratch: &mut Vec<f32>,
    out_arena: &mut [f32],
) {
    let rows = blk.len() / d;
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: see dot_rows_i8.
        Isa::Avx2 if detect() == Isa::Avx2 => unsafe {
            ensure_scratch(scratch, rows * d);
            for r in 0..rows {
                avx2::dequantize_row_into(
                    &blk[r * d..(r + 1) * d],
                    scales,
                    &mut scratch[r * d..(r + 1) * d],
                );
            }
            for m in members {
                avx2::dot_rows_f32(
                    &q_arena[m.inp..m.inp + d],
                    &scratch[..rows * d],
                    &mut out_arena[m.out..m.out + rows],
                );
            }
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: see dot_rows_i8.
        Isa::Neon if detect() == Isa::Neon => unsafe {
            for m in members {
                neon::dot_rows_i8(
                    &q_arena[m.inp..m.inp + d],
                    blk,
                    scales,
                    &mut out_arena[m.out..m.out + rows],
                );
            }
        },
        _ => attn::dot_rows_i8_mq(variant, d, q_arena, blk, scales, members, out_arena),
    }
}

/// Fused multi-query softmax·V accumulation over an INT8 slab. The
/// accumulate kernels have no cross-channel sums on any backend, so
/// dequantize-once composition is bit-safe everywhere: AVX2 and NEON
/// unpack the slab once into `scratch` and run the f32 accumulate per
/// member; scalar fans the products out directly
/// ([`attn::accumulate_rows_i8_mq`]). Bit-identical to per-member
/// [`accumulate_rows_i8`] calls on every backend.
#[allow(unused_variables)]
pub fn accumulate_rows_i8_mq(
    isa: Isa,
    variant: Variant,
    d: usize,
    w_arena: &[f32],
    blk: &[i8],
    scales: &[f32],
    members: &[MqMember],
    scratch: &mut Vec<f32>,
    acc_arena: &mut [f32],
) {
    let rows = blk.len() / d;
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: see dot_rows_i8.
        Isa::Avx2 if detect() == Isa::Avx2 => unsafe {
            ensure_scratch(scratch, rows * d);
            for r in 0..rows {
                avx2::dequantize_row_into(
                    &blk[r * d..(r + 1) * d],
                    scales,
                    &mut scratch[r * d..(r + 1) * d],
                );
            }
            for m in members {
                avx2::accumulate_rows_f32(
                    &w_arena[m.inp..m.inp + rows],
                    &scratch[..rows * d],
                    &mut acc_arena[m.out..m.out + d],
                );
            }
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: see dot_rows_i8.
        Isa::Neon if detect() == Isa::Neon => unsafe {
            ensure_scratch(scratch, rows * d);
            for r in 0..rows {
                neon::dequantize_row_into(
                    &blk[r * d..(r + 1) * d],
                    scales,
                    &mut scratch[r * d..(r + 1) * d],
                );
            }
            for m in members {
                neon::accumulate_rows_f32(
                    &w_arena[m.inp..m.inp + rows],
                    &scratch[..rows * d],
                    &mut acc_arena[m.out..m.out + d],
                );
            }
        },
        _ => attn::accumulate_rows_i8_mq(variant, d, w_arena, blk, scales, members, acc_arena),
    }
}

/// FP32 multi-query dot: nothing to dequantize, so every backend loops
/// members over the shared slab (bandwidth amortization only).
/// Bit-identical to per-member [`dot_rows_f32`] calls on the same `isa`.
pub fn dot_rows_f32_mq(
    isa: Isa,
    d: usize,
    q_arena: &[f32],
    blk: &[f32],
    members: &[MqMember],
    out_arena: &mut [f32],
) {
    if isa == Isa::Scalar {
        attn::dot_rows_f32_mq(d, q_arena, blk, members, out_arena);
        return;
    }
    let rows = blk.len() / d;
    for m in members {
        dot_rows_f32(isa, &q_arena[m.inp..m.inp + d], blk, &mut out_arena[m.out..m.out + rows]);
    }
}

/// FP32 multi-query accumulate; see [`dot_rows_f32_mq`].
pub fn accumulate_rows_f32_mq(
    isa: Isa,
    d: usize,
    w_arena: &[f32],
    blk: &[f32],
    members: &[MqMember],
    acc_arena: &mut [f32],
) {
    if isa == Isa::Scalar {
        attn::accumulate_rows_f32_mq(d, w_arena, blk, members, acc_arena);
        return;
    }
    let rows = blk.len() / d;
    for m in members {
        accumulate_rows_f32(
            isa,
            &w_arena[m.inp..m.inp + rows],
            blk,
            &mut acc_arena[m.out..m.out + d],
        );
    }
}

/// Multi-query dot over a nibble-packed INT4 slab: each row is unpacked
/// into `scratch` **once** and dotted for every member before moving on
/// (the single-query path unpacks per (query, row)). Unpack values and
/// the per-member one-row dot are identical to the single-query path,
/// so this is bit-identical to per-member [`dot_rows_i4`] calls on
/// every backend.
pub fn dot_rows_i4_mq(
    isa: Isa,
    d: usize,
    q_arena: &[f32],
    blk: &[u8],
    scales: &[f32],
    members: &[MqMember],
    scratch: &mut Vec<f32>,
    out_arena: &mut [f32],
) {
    let bpr = d.div_ceil(2);
    debug_assert_eq!(blk.len() % bpr, 0, "slab shape mismatch");
    let rows = blk.len() / bpr;
    ensure_scratch(scratch, d);
    for r in 0..rows {
        dequantize4_row_into(isa, &blk[r * bpr..(r + 1) * bpr], scales, &mut scratch[..d]);
        for m in members {
            let q = &q_arena[m.inp..m.inp + d];
            if isa == Isa::Scalar {
                let mut dot = 0.0f32;
                for ch in 0..d {
                    dot += q[ch] * scratch[ch];
                }
                out_arena[m.out + r] = dot;
            } else {
                let mut one = [0.0f32];
                dot_rows_f32(isa, q, &scratch[..d], &mut one);
                out_arena[m.out + r] = one[0];
            }
        }
    }
}

/// Multi-query softmax·V accumulation over a nibble-packed INT4 slab;
/// rows outer (each unpacked once), members inner — every member still
/// sees rows in ascending order, so this is bit-identical to per-member
/// [`accumulate_rows_i4`] calls on every backend.
pub fn accumulate_rows_i4_mq(
    isa: Isa,
    d: usize,
    w_arena: &[f32],
    blk: &[u8],
    scales: &[f32],
    members: &[MqMember],
    scratch: &mut Vec<f32>,
    acc_arena: &mut [f32],
) {
    let bpr = d.div_ceil(2);
    debug_assert_eq!(blk.len() % bpr, 0, "slab shape mismatch");
    let rows = blk.len() / bpr;
    ensure_scratch(scratch, d);
    for r in 0..rows {
        dequantize4_row_into(isa, &blk[r * bpr..(r + 1) * bpr], scales, &mut scratch[..d]);
        for m in members {
            let wr = w_arena[m.inp + r];
            let acc = &mut acc_arena[m.out..m.out + d];
            if isa == Isa::Scalar {
                for ch in 0..d {
                    acc[ch] += wr * scratch[ch];
                }
            } else {
                accumulate_rows_f32(isa, &[wr], &scratch[..d], acc);
            }
        }
    }
}

/// Fused dequant·dot over a nibble-packed INT4 slab. Each row is
/// unpacked into the O(d) `scratch` and dotted. The scalar arm is the
/// pre-backend `Int4Codec::dot_rows` loop, bit for bit; the SIMD arm is
/// the *composition* of the SIMD nibble unpack and the SIMD f32 dot —
/// there is no extra fusion to hand-write per arch, so it lives here
/// once instead of twice in avx2.rs/neon.rs.
pub fn dot_rows_i4(
    isa: Isa,
    q: &[f32],
    blk: &[u8],
    scales: &[f32],
    scratch: &mut Vec<f32>,
    out: &mut [f32],
) {
    let d = q.len();
    let bpr = d.div_ceil(2);
    debug_assert_eq!(blk.len(), out.len() * bpr, "slab shape mismatch");
    ensure_scratch(scratch, d);
    match isa {
        Isa::Scalar => {
            for (r, o) in out.iter_mut().enumerate() {
                int4::dequantize4_row_into(&blk[r * bpr..(r + 1) * bpr], scales, &mut scratch[..d]);
                let mut dot = 0.0f32;
                for ch in 0..d {
                    dot += q[ch] * scratch[ch];
                }
                *o = dot;
            }
        }
        _ => {
            for (r, o) in out.iter_mut().enumerate() {
                dequantize4_row_into(isa, &blk[r * bpr..(r + 1) * bpr], scales, &mut scratch[..d]);
                let mut one = [0.0f32];
                dot_rows_f32(isa, q, &scratch[..d], &mut one);
                *o = one[0];
            }
        }
    }
}

/// Fused softmax·V accumulation over a nibble-packed INT4 slab;
/// bit-identical across backends (unpack and per-channel multiply-add
/// are exact in the scalar order). SIMD arm composed from the SIMD
/// unpack + f32 accumulate, like [`dot_rows_i4`].
pub fn accumulate_rows_i4(
    isa: Isa,
    w: &[f32],
    blk: &[u8],
    scales: &[f32],
    scratch: &mut Vec<f32>,
    acc: &mut [f32],
) {
    let d = acc.len();
    let bpr = d.div_ceil(2);
    debug_assert_eq!(blk.len(), w.len() * bpr, "slab shape mismatch");
    ensure_scratch(scratch, d);
    match isa {
        Isa::Scalar => {
            for (r, &wr) in w.iter().enumerate() {
                int4::dequantize4_row_into(&blk[r * bpr..(r + 1) * bpr], scales, &mut scratch[..d]);
                for ch in 0..d {
                    acc[ch] += wr * scratch[ch];
                }
            }
        }
        _ => {
            for (r, &wr) in w.iter().enumerate() {
                dequantize4_row_into(isa, &blk[r * bpr..(r + 1) * bpr], scales, &mut scratch[..d]);
                accumulate_rows_f32(isa, &[wr], &scratch[..d], acc);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Row encode / decode dispatchers (the cache-writer and unpack paths).
// ---------------------------------------------------------------------------

/// INT8 row encode through the selected backend — bit-identical to
/// [`quantize::quantize_row_into`] on every backend (module docs).
#[inline]
pub fn quantize_row_into(isa: Isa, row: &[f32], scales: &[f32], out: &mut [i8]) {
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: see dot_rows_i8.
        Isa::Avx2 if detect() == Isa::Avx2 => unsafe {
            avx2::quantize_row_into(row, scales, out)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: see dot_rows_i8.
        Isa::Neon if detect() == Isa::Neon => unsafe {
            neon::quantize_row_into(row, scales, out)
        },
        _ => quantize::quantize_row_into(row, scales, out),
    }
}

/// INT8 row decode — bit-identical across backends.
#[inline]
pub fn dequantize_row_into(isa: Isa, row: &[i8], scales: &[f32], out: &mut [f32]) {
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: see dot_rows_i8.
        Isa::Avx2 if detect() == Isa::Avx2 => unsafe {
            avx2::dequantize_row_into(row, scales, out)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: see dot_rows_i8.
        Isa::Neon if detect() == Isa::Neon => unsafe {
            neon::dequantize_row_into(row, scales, out)
        },
        _ => dequantize::dequantize_row_into(row, scales, out),
    }
}

/// INT4 row encode (packed nibbles) — bit-identical to
/// [`int4::quantize4_row_into`] on every backend.
#[inline]
pub fn quantize4_row_into(isa: Isa, row: &[f32], scales: &[f32], out: &mut [u8]) {
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: see dot_rows_i8.
        Isa::Avx2 if detect() == Isa::Avx2 => unsafe {
            avx2::quantize4_row_into(row, scales, out)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: see dot_rows_i8.
        Isa::Neon if detect() == Isa::Neon => unsafe {
            neon::quantize4_row_into(row, scales, out)
        },
        _ => int4::quantize4_row_into(row, scales, out),
    }
}

/// INT4 row decode (nibble unpack + dequantize) — bit-identical across
/// backends.
#[inline]
pub fn dequantize4_row_into(isa: Isa, bytes: &[u8], scales: &[f32], out: &mut [f32]) {
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: see dot_rows_i8.
        Isa::Avx2 if detect() == Isa::Avx2 => unsafe {
            avx2::dequantize4_row_into(bytes, scales, out)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: see dot_rows_i8.
        Isa::Neon if detect() == Isa::Neon => unsafe {
            neon::dequantize4_row_into(bytes, scales, out)
        },
        _ => int4::dequantize4_row_into(bytes, scales, out),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::matrix::Fp32Matrix;
    use crate::quant::quantize::quantize_fused;
    use crate::quant::scales::compute_scales;
    use crate::util::rng::Rng;

    #[test]
    fn backend_parse_and_name_roundtrip() {
        for kb in [KernelBackend::Auto, KernelBackend::Scalar, KernelBackend::Simd] {
            assert_eq!(KernelBackend::parse(kb.name()), Some(kb));
        }
        assert_eq!(KernelBackend::parse("avx512"), None);
        for isa in [Isa::Scalar, Isa::Avx2, Isa::Neon] {
            assert!(!isa.name().is_empty());
        }
        assert!(!Isa::Scalar.is_simd());
        assert!(Isa::Avx2.is_simd() && Isa::Neon.is_simd());
    }

    #[test]
    fn resolution_rules() {
        // scalar always resolves to the scalar ISA; auto/simd resolve to
        // whatever this host detects; the env override wins.
        assert_eq!(KernelBackend::Scalar.resolve_with(None), Isa::Scalar);
        assert_eq!(KernelBackend::Auto.resolve_with(None), detect());
        assert_eq!(KernelBackend::Simd.resolve_with(None), detect());
        assert_eq!(KernelBackend::Auto.resolve_with(Some("scalar")), Isa::Scalar);
        assert_eq!(KernelBackend::Scalar.resolve_with(Some("simd")), detect());
        // Unparseable env values are ignored.
        assert_eq!(KernelBackend::Scalar.resolve_with(Some("warp")), Isa::Scalar);
        // The detected ISA matches this build's architecture.
        match detect() {
            Isa::Avx2 => assert!(cfg!(target_arch = "x86_64")),
            Isa::Neon => assert!(cfg!(target_arch = "aarch64")),
            Isa::Scalar => {}
        }
    }

    #[test]
    fn scalar_dispatch_is_the_scalar_kernel() {
        // Isa::Scalar must route to the exact legacy code paths.
        let k = Fp32Matrix::random_normal(5, 19, 1.0, 0x5CA);
        let q8 = quantize_fused(&k);
        let mut rng = Rng::new(1);
        let mut q = vec![0.0f32; 19];
        rng.fill_uniform(&mut q, -1.0, 1.0);
        let bits = |x: &[f32]| x.iter().map(|v| v.to_bits()).collect::<Vec<_>>();

        let mut want = vec![0.0f32; 5];
        attn::dot_rows_i8(Variant::Vectorized, &q, &q8.data, &q8.scales, &mut want);
        let mut got = vec![0.0f32; 5];
        dot_rows_i8(Isa::Scalar, Variant::Vectorized, &q, &q8.data, &q8.scales, &mut got);
        assert_eq!(bits(&got), bits(&want));

        let mut out_a = vec![0i8; 19];
        let mut out_b = vec![0i8; 19];
        quantize::quantize_row_into(k.row(2), &q8.scales, &mut out_a);
        quantize_row_into(Isa::Scalar, k.row(2), &q8.scales, &mut out_b);
        assert_eq!(out_a, out_b);
    }

    /// A misreported ISA (e.g. `Isa::Neon` on x86_64) silently falls
    /// back to scalar instead of executing unsupported instructions.
    #[test]
    fn mismatched_isa_falls_back_to_scalar() {
        let wrong = if cfg!(target_arch = "x86_64") { Isa::Neon } else { Isa::Avx2 };
        let row = [0.5f32, -1.5, 2.0, 0.25, -0.125];
        let scales = [0.01f32; 5];
        let mut a = vec![0i8; 5];
        let mut b = vec![0i8; 5];
        quantize_row_into(wrong, &row, &scales, &mut a);
        quantize::quantize_row_into(&row, &scales, &mut b);
        assert_eq!(a, b);
    }

    /// The cross-backend contract on this host's detected SIMD ISA:
    /// encode/decode/accumulate bit-identical to scalar, dot within the
    /// f64-reference tolerance. Degenerates to scalar-vs-scalar (still a
    /// valid dispatch check) on hosts without SIMD.
    #[test]
    fn simd_matches_scalar_per_contract() {
        let isa = detect();
        for (rows, d) in [(1usize, 1usize), (3, 3), (2, 7), (5, 8), (4, 9), (7, 16), (3, 64)] {
            let k = Fp32Matrix::random_normal(rows, d, 1.0, (rows * 37 + d) as u64);
            let s = compute_scales(&k);
            let q8 = quantize_fused(&k);
            let mut rng = Rng::new((rows + d) as u64);
            let mut q = vec![0.0f32; d];
            let mut w = vec![0.0f32; rows];
            rng.fill_uniform(&mut q, -1.0, 1.0);
            rng.fill_uniform(&mut w, 0.0, 1.0);

            // Encode: bit-identical codes.
            for t in 0..rows {
                let mut scalar = vec![0i8; d];
                let mut simd = vec![0i8; d];
                quantize::quantize_row_into(k.row(t), &s, &mut scalar);
                quantize_row_into(isa, k.row(t), &s, &mut simd);
                assert_eq!(scalar, simd, "encode {rows}x{d} row {t} on {}", isa.name());
            }

            // Decode: bit-identical floats.
            let bits = |x: &[f32]| x.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
            let mut scalar_dec = vec![0.0f32; d];
            let mut simd_dec = vec![0.0f32; d];
            dequantize::dequantize_row_into(&q8.data[..d], &q8.scales, &mut scalar_dec);
            dequantize_row_into(isa, &q8.data[..d], &q8.scales, &mut simd_dec);
            assert_eq!(bits(&scalar_dec), bits(&simd_dec), "decode {rows}x{d}");

            // Accumulate: bit-identical (same op order per channel).
            let mut scalar_acc = vec![0.1f32; d];
            let mut simd_acc = vec![0.1f32; d];
            attn::accumulate_rows_i8(Variant::Naive, &w, &q8.data, &q8.scales, &mut scalar_acc);
            accumulate_rows_i8(isa, Variant::Naive, &w, &q8.data, &q8.scales, &mut simd_acc);
            assert_eq!(bits(&scalar_acc), bits(&simd_acc), "accumulate {rows}x{d}");

            // Dot: f64-reference tolerance (lane sums reassociate).
            let mut got = vec![0.0f32; rows];
            dot_rows_i8(isa, Variant::Vectorized, &q, &q8.data, &q8.scales, &mut got);
            for r in 0..rows {
                let mut reference = 0.0f64;
                let mut magnitude = 0.0f64;
                for ch in 0..d {
                    let term =
                        q[ch] as f64 * (q8.data[r * d + ch] as f64 * q8.scales[ch] as f64);
                    reference += term;
                    magnitude += term.abs();
                }
                let tol = 1e-5 * (d as f64) * magnitude + 1e-6;
                assert!(
                    (got[r] as f64 - reference).abs() <= tol,
                    "dot {rows}x{d} row {r}: {} vs {reference} on {}",
                    got[r],
                    isa.name()
                );
            }
        }
    }

    #[test]
    fn simd_encode_matches_scalar_on_edge_values() {
        // Ties, NaN, infinities, zero/negative scales — the pinned
        // quantize_one semantics must survive every backend.
        let isa = detect();
        let row = [
            0.5f32,
            -0.5,
            1.5,
            -1.5,
            0.49999997,
            f32::NAN,
            f32::INFINITY,
            f32::NEG_INFINITY,
            1e9,
            -1e9,
            0.0,
            -0.0,
        ];
        for scale in [1.0f32, 0.25, 0.0, -1.0, f32::NAN] {
            let scales = vec![scale; row.len()];
            let mut scalar = vec![0i8; row.len()];
            let mut simd = vec![0i8; row.len()];
            quantize::quantize_row_into(&row, &scales, &mut scalar);
            quantize_row_into(isa, &row, &scales, &mut simd);
            assert_eq!(scalar, simd, "scale {scale} on {}", isa.name());
        }
    }

    #[test]
    fn int4_paths_match_scalar_per_contract() {
        let isa = detect();
        for (rows, d) in [(1usize, 2usize), (3, 8), (5, 10), (2, 16), (4, 64)] {
            let k = Fp32Matrix::random_uniform(rows, d, -2.0, 2.0, (rows * 7 + d) as u64);
            let q4 = int4::quantize4(&k);
            let bpr = d / 2;

            // Encode: bit-identical packed bytes.
            for t in 0..rows {
                let mut scalar = vec![0u8; bpr];
                let mut simd = vec![0u8; bpr];
                int4::quantize4_row_into(k.row(t), &q4.scales, &mut scalar);
                quantize4_row_into(isa, k.row(t), &q4.scales, &mut simd);
                assert_eq!(scalar, simd, "int4 encode {rows}x{d} row {t}");
            }

            // Decode: bit-identical floats.
            let bits = |x: &[f32]| x.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
            let mut scalar_dec = vec![0.0f32; d];
            let mut simd_dec = vec![0.0f32; d];
            int4::dequantize4_row_into(&q4.data[..bpr], &q4.scales, &mut scalar_dec);
            dequantize4_row_into(isa, &q4.data[..bpr], &q4.scales, &mut simd_dec);
            assert_eq!(bits(&scalar_dec), bits(&simd_dec), "int4 decode {rows}x{d}");

            // Fused dot/accumulate vs the scalar arm.
            let mut rng = Rng::new(d as u64);
            let mut q = vec![0.0f32; d];
            let mut w = vec![0.0f32; rows];
            rng.fill_uniform(&mut q, -1.0, 1.0);
            rng.fill_uniform(&mut w, 0.0, 1.0);
            let mut scratch = Vec::new();
            let mut scalar_out = vec![0.0f32; rows];
            dot_rows_i4(Isa::Scalar, &q, &q4.data, &q4.scales, &mut scratch, &mut scalar_out);
            let mut simd_out = vec![0.0f32; rows];
            dot_rows_i4(isa, &q, &q4.data, &q4.scales, &mut scratch, &mut simd_out);
            for r in 0..rows {
                assert!(
                    (scalar_out[r] - simd_out[r]).abs()
                        <= 1e-5 * scalar_out[r].abs().max(1.0) * d as f32,
                    "int4 dot {rows}x{d} row {r}"
                );
            }
            let mut scalar_acc = vec![0.25f32; d];
            let mut simd_acc = vec![0.25f32; d];
            accumulate_rows_i4(
                Isa::Scalar,
                &w,
                &q4.data,
                &q4.scales,
                &mut scratch,
                &mut scalar_acc,
            );
            accumulate_rows_i4(isa, &w, &q4.data, &q4.scales, &mut scratch, &mut simd_acc);
            assert_eq!(bits(&scalar_acc), bits(&simd_acc), "int4 accumulate {rows}x{d}");
        }
    }

    /// The multi-query contract: on EVERY backend (scalar and whatever
    /// this host detects), each member of an mq call gets exactly the
    /// bits of a per-member single-query call on the same backend — the
    /// amortized slab read can never change a score or an accumulation.
    #[test]
    fn mq_dispatchers_bit_identical_to_per_member_single_query() {
        let bits = |x: &[f32]| x.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        for isa in [Isa::Scalar, detect()] {
            for (rows, d, n) in [(1usize, 1usize, 1usize), (4, 8, 3), (7, 16, 4), (3, 9, 2)] {
                let k = Fp32Matrix::random_normal(rows, d, 1.0, (rows * 13 + d + n) as u64);
                let q8 = quantize_fused(&k);
                let mut rng = Rng::new((rows + d * n) as u64);
                let mut q_arena = vec![0.0f32; n * d];
                let mut w_arena = vec![0.0f32; n * rows];
                rng.fill_uniform(&mut q_arena, -1.0, 1.0);
                rng.fill_uniform(&mut w_arena, 0.0, 1.0);
                let dot_members: Vec<MqMember> =
                    (0..n).map(|i| MqMember { inp: i * d, out: i * rows }).collect();
                let acc_members: Vec<MqMember> =
                    (0..n).map(|i| MqMember { inp: i * rows, out: i * d }).collect();
                let mut scratch = Vec::new();

                // INT8 dot + accumulate.
                for variant in Variant::ALL {
                    let mut out_arena = vec![0.0f32; n * rows];
                    dot_rows_i8_mq(
                        isa,
                        variant,
                        d,
                        &q_arena,
                        &q8.data,
                        &q8.scales,
                        &dot_members,
                        &mut scratch,
                        &mut out_arena,
                    );
                    let mut acc_arena = vec![0.5f32; n * d];
                    accumulate_rows_i8_mq(
                        isa,
                        variant,
                        d,
                        &w_arena,
                        &q8.data,
                        &q8.scales,
                        &acc_members,
                        &mut scratch,
                        &mut acc_arena,
                    );
                    for i in 0..n {
                        let mut want = vec![0.0f32; rows];
                        dot_rows_i8(
                            isa,
                            variant,
                            &q_arena[i * d..(i + 1) * d],
                            &q8.data,
                            &q8.scales,
                            &mut want,
                        );
                        assert_eq!(
                            bits(&out_arena[i * rows..(i + 1) * rows]),
                            bits(&want),
                            "i8 mq dot {rows}x{d} member {i} on {} {variant:?}",
                            isa.name()
                        );
                        let mut want_acc = vec![0.5f32; d];
                        accumulate_rows_i8(
                            isa,
                            variant,
                            &w_arena[i * rows..(i + 1) * rows],
                            &q8.data,
                            &q8.scales,
                            &mut want_acc,
                        );
                        assert_eq!(
                            bits(&acc_arena[i * d..(i + 1) * d]),
                            bits(&want_acc),
                            "i8 mq accumulate {rows}x{d} member {i} on {} {variant:?}",
                            isa.name()
                        );
                    }
                }

                // FP32 twins.
                let mut out_arena = vec![0.0f32; n * rows];
                dot_rows_f32_mq(isa, d, &q_arena, &k.data, &dot_members, &mut out_arena);
                let mut acc_arena = vec![0.25f32; n * d];
                accumulate_rows_f32_mq(isa, d, &w_arena, &k.data, &acc_members, &mut acc_arena);
                for i in 0..n {
                    let mut want = vec![0.0f32; rows];
                    dot_rows_f32(isa, &q_arena[i * d..(i + 1) * d], &k.data, &mut want);
                    assert_eq!(
                        bits(&out_arena[i * rows..(i + 1) * rows]),
                        bits(&want),
                        "f32 mq dot member {i} on {}",
                        isa.name()
                    );
                    let mut want_acc = vec![0.25f32; d];
                    accumulate_rows_f32(
                        isa,
                        &w_arena[i * rows..(i + 1) * rows],
                        &k.data,
                        &mut want_acc,
                    );
                    assert_eq!(
                        bits(&acc_arena[i * d..(i + 1) * d]),
                        bits(&want_acc),
                        "f32 mq accumulate member {i} on {}",
                        isa.name()
                    );
                }

                // INT4 (even d only: nibble rows).
                if d % 2 == 0 {
                    let q4 = int4::quantize4(&k);
                    let mut out_arena = vec![0.0f32; n * rows];
                    dot_rows_i4_mq(
                        isa,
                        d,
                        &q_arena,
                        &q4.data,
                        &q4.scales,
                        &dot_members,
                        &mut scratch,
                        &mut out_arena,
                    );
                    let mut acc_arena = vec![0.125f32; n * d];
                    accumulate_rows_i4_mq(
                        isa,
                        d,
                        &w_arena,
                        &q4.data,
                        &q4.scales,
                        &acc_members,
                        &mut scratch,
                        &mut acc_arena,
                    );
                    for i in 0..n {
                        let mut want = vec![0.0f32; rows];
                        dot_rows_i4(
                            isa,
                            &q_arena[i * d..(i + 1) * d],
                            &q4.data,
                            &q4.scales,
                            &mut scratch,
                            &mut want,
                        );
                        assert_eq!(
                            bits(&out_arena[i * rows..(i + 1) * rows]),
                            bits(&want),
                            "i4 mq dot {rows}x{d} member {i} on {}",
                            isa.name()
                        );
                        let mut want_acc = vec![0.125f32; d];
                        accumulate_rows_i4(
                            isa,
                            &w_arena[i * rows..(i + 1) * rows],
                            &q4.data,
                            &q4.scales,
                            &mut scratch,
                            &mut want_acc,
                        );
                        assert_eq!(
                            bits(&acc_arena[i * d..(i + 1) * d]),
                            bits(&want_acc),
                            "i4 mq accumulate {rows}x{d} member {i} on {}",
                            isa.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn f32_twins_match_scalar_per_contract() {
        let isa = detect();
        for (rows, d) in [(1usize, 3usize), (4, 8), (3, 21), (2, 64)] {
            let k = Fp32Matrix::random_normal(rows, d, 1.0, (rows + d) as u64);
            let mut rng = Rng::new(9);
            let mut q = vec![0.0f32; d];
            let mut w = vec![0.0f32; rows];
            rng.fill_uniform(&mut q, -1.0, 1.0);
            rng.fill_uniform(&mut w, 0.0, 1.0);
            let bits = |x: &[f32]| x.iter().map(|v| v.to_bits()).collect::<Vec<_>>();

            let mut scalar_acc = vec![0.5f32; d];
            let mut simd_acc = vec![0.5f32; d];
            attn::accumulate_rows_f32(&w, &k.data, &mut scalar_acc);
            accumulate_rows_f32(isa, &w, &k.data, &mut simd_acc);
            assert_eq!(bits(&scalar_acc), bits(&simd_acc), "f32 accumulate {rows}x{d}");

            let mut scalar_out = vec![0.0f32; rows];
            let mut simd_out = vec![0.0f32; rows];
            attn::dot_rows_f32(&q, &k.data, &mut scalar_out);
            dot_rows_f32(isa, &q, &k.data, &mut simd_out);
            for r in 0..rows {
                assert!(
                    (scalar_out[r] - simd_out[r]).abs()
                        <= 1e-5 * scalar_out[r].abs().max(1.0) * d as f32,
                    "f32 dot {rows}x{d} row {r}"
                );
            }
        }
    }
}
