//! Per-channel scale computation — Algorithm 1 of the paper.
//!
//! `s_d = max_t |K[t,d]| / 127`. The naive port walks each column with a
//! stride-D access pattern exactly like the paper's C (Listing 2); the
//! row-sweep variant is the cache-friendly rewrite (one sequential pass,
//! maintaining all D running maxima) that the optimized quantizers use.

use super::matrix::Fp32Matrix;
use crate::parallel as pool;
use crate::QMAX;

/// Paper Listing 2, verbatim structure: column-outer, row-inner (stride-D
/// loads). O(T·D) with poor locality — kept as the faithful CPU baseline.
pub fn compute_scales_naive(k: &Fp32Matrix, scales: &mut [f32]) {
    assert_eq!(scales.len(), k.cols);
    for d in 0..k.cols {
        let mut max_abs = 0.0f32;
        for t in 0..k.rows {
            let val = k.data[t * k.cols + d].abs();
            if val > max_abs {
                max_abs = val;
            }
        }
        scales[d] = max_abs / QMAX;
    }
}

/// Cache-friendly single sequential pass: maintain all D running maxima
/// while sweeping rows. Same result, ~D-way better locality.
pub fn compute_scales_rowsweep(k: &Fp32Matrix, scales: &mut [f32]) {
    assert_eq!(scales.len(), k.cols);
    let mut maxima = vec![0.0f32; k.cols];
    for t in 0..k.rows {
        let row = k.row(t);
        for (m, v) in maxima.iter_mut().zip(row) {
            let a = v.abs();
            if a > *m {
                *m = a;
            }
        }
    }
    for (s, m) in scales.iter_mut().zip(&maxima) {
        *s = m / QMAX;
    }
}

/// Multi-threaded row-sweep: each worker reduces a row range, then maxima
/// are merged. Degrades to `compute_scales_rowsweep` on 1 thread.
pub fn compute_scales_parallel(k: &Fp32Matrix, scales: &mut [f32], threads: usize) {
    assert_eq!(scales.len(), k.cols);
    let threads = threads.max(1);
    if threads == 1 || k.rows < 2 * threads {
        return compute_scales_rowsweep(k, scales);
    }
    let per = k.rows.div_ceil(threads);
    let partials: Vec<Vec<f32>> = pool::parallel_map(
        &(0..threads).collect::<Vec<_>>(),
        threads,
        |&w| {
            let lo = w * per;
            let hi = ((w + 1) * per).min(k.rows);
            let mut maxima = vec![0.0f32; k.cols];
            for t in lo..hi {
                for (m, v) in maxima.iter_mut().zip(k.row(t)) {
                    let a = v.abs();
                    if a > *m {
                        *m = a;
                    }
                }
            }
            maxima
        },
    );
    let mut maxima = vec![0.0f32; k.cols];
    for p in &partials {
        for (m, v) in maxima.iter_mut().zip(p) {
            if v > m {
                *m = *v;
            }
        }
    }
    for (s, m) in scales.iter_mut().zip(&maxima) {
        *s = m / QMAX;
    }
}

/// Default entry point (row-sweep).
pub fn compute_scales(k: &Fp32Matrix) -> Vec<f32> {
    let mut scales = vec![0.0; k.cols];
    compute_scales_rowsweep(k, &mut scales);
    scales
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Fp32Matrix {
        Fp32Matrix::random_normal(128, 48, 1.0, 42)
    }

    #[test]
    fn naive_matches_hand_computed() {
        // Column maxima 127 and 254 -> scales exactly 1 and 2 (paper §7.5
        // "deterministic tests validate scale computation").
        let k = Fp32Matrix::from_vec(2, 2, vec![127.0, -254.0, -1.0, 2.0]);
        let mut s = vec![0.0; 2];
        compute_scales_naive(&k, &mut s);
        assert_eq!(s, vec![1.0, 2.0]);
    }

    #[test]
    fn all_variants_agree() {
        let k = sample();
        let mut a = vec![0.0; k.cols];
        let mut b = vec![0.0; k.cols];
        let mut c = vec![0.0; k.cols];
        compute_scales_naive(&k, &mut a);
        compute_scales_rowsweep(&k, &mut b);
        compute_scales_parallel(&k, &mut c, 4);
        assert_eq!(a, b);
        assert_eq!(a, c);
    }

    #[test]
    fn zero_column_zero_scale() {
        let mut k = Fp32Matrix::zeros(16, 4);
        k.data[3] = 5.0; // only column 3 nonzero
        let s = compute_scales(&k);
        assert_eq!(s[0], 0.0);
        assert!((s[3] - 5.0 / 127.0).abs() < 1e-9);
    }

    #[test]
    fn negative_values_count_via_abs() {
        let k = Fp32Matrix::from_vec(2, 1, vec![-10.0, 5.0]);
        let s = compute_scales(&k);
        assert!((s[0] - 10.0 / 127.0).abs() < 1e-9);
    }

    #[test]
    fn single_row_matrix() {
        let k = Fp32Matrix::from_vec(1, 3, vec![0.5, -0.25, 0.0]);
        let s = compute_scales(&k);
        assert!((s[0] - 0.5 / 127.0).abs() < 1e-9);
        assert_eq!(s[2], 0.0);
    }

    #[test]
    fn parallel_small_matrix_falls_back() {
        let k = Fp32Matrix::random_uniform(3, 8, -1.0, 1.0, 1);
        let mut a = vec![0.0; 8];
        let mut b = vec![0.0; 8];
        compute_scales_parallel(&k, &mut a, 8);
        compute_scales_rowsweep(&k, &mut b);
        assert_eq!(a, b);
    }
}
