//! Dequantization — eq. (8): `x̂ = x_q · s_d` (paper Listing 4).

use super::matrix::{Fp32Matrix, Int8Matrix};
use super::quantize::ROW_CHUNK;
use crate::parallel::{self, SendPtr};

/// Dequantize into a preallocated matrix (hot-path form).
pub fn dequantize_into(q: &Int8Matrix, out: &mut Fp32Matrix) {
    assert_eq!((out.rows, out.cols), (q.rows, q.cols), "out shape mismatch");
    let cols = q.cols;
    for t in 0..q.rows {
        let src = &q.data[t * cols..(t + 1) * cols];
        let dst = &mut out.data[t * cols..(t + 1) * cols];
        for ((o, &v), &s) in dst.iter_mut().zip(src).zip(&q.scales) {
            *o = v as f32 * s;
        }
    }
}

/// Allocate-and-dequantize convenience.
pub fn dequantize(q: &Int8Matrix) -> Fp32Matrix {
    let mut out = Fp32Matrix::zeros(q.rows, q.cols);
    dequantize_into(q, &mut out);
    out
}

/// Dequantize a single row (serving gather path).
#[inline]
pub fn dequantize_row_into(row: &[i8], scales: &[f32], out: &mut [f32]) {
    for ((o, &v), &s) in out.iter_mut().zip(row).zip(scales) {
        *o = v as f32 * s;
    }
}

/// Multi-threaded dequantization, row-partitioned through the shared
/// [`crate::parallel`] runtime. Bit-identical to [`dequantize_into`] at
/// any thread count (same per-element multiply; workers own disjoint
/// rows).
pub fn dequantize_parallel(q: &Int8Matrix, out: &mut Fp32Matrix, threads: usize) {
    assert_eq!((out.rows, out.cols), (q.rows, q.cols), "out shape mismatch");
    let cols = q.cols;
    let out_ptr = SendPtr::new(out.data.as_mut_ptr());
    parallel::parallel_chunks(q.rows, ROW_CHUNK, threads, |lo, hi| {
        for t in lo..hi {
            let src = &q.data[t * cols..(t + 1) * cols];
            // SAFETY: row ranges [lo, hi) are disjoint across workers.
            let dst = unsafe { std::slice::from_raw_parts_mut(out_ptr.add(t * cols), cols) };
            dequantize_row_into(src, &q.scales, dst);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quantize::quantize_fused;

    #[test]
    fn dequantize_hand_values() {
        let q = Int8Matrix {
            rows: 2,
            cols: 2,
            data: vec![127, -64, 0, 1],
            scales: vec![0.01, 2.0],
        };
        let out = dequantize(&q);
        assert_eq!(out.data, vec![1.27, -128.0, 0.0, 2.0]);
    }

    #[test]
    fn roundtrip_error_bounded_by_half_scale() {
        // eq. (9): |x - x̂| <= s/2.
        let k = Fp32Matrix::random_uniform(256, 64, -1.0, 1.0, 3);
        let q = quantize_fused(&k);
        let r = dequantize(&q);
        for t in 0..k.rows {
            for d in 0..k.cols {
                let err = (k.at(t, d) - r.at(t, d)).abs();
                assert!(
                    err <= q.scales[d] / 2.0 + 1e-7,
                    "err {err} > s/2 {} at ({t},{d})",
                    q.scales[d] / 2.0
                );
            }
        }
    }

    #[test]
    fn zeros_roundtrip_exactly() {
        let k = Fp32Matrix::zeros(8, 8);
        let q = quantize_fused(&k);
        let r = dequantize(&q);
        assert_eq!(r.data, k.data);
    }

    #[test]
    fn column_extremes_roundtrip_exactly() {
        // The per-column abs max quantizes to ±127 and dequantizes to
        // exactly ±max (s = max/127, 127*s = max up to fp rounding).
        let k = Fp32Matrix::from_vec(2, 1, vec![0.75, -0.375]);
        let q = quantize_fused(&k);
        let r = dequantize(&q);
        assert!((r.at(0, 0) - 0.75).abs() < 1e-7);
    }

    #[test]
    fn parallel_matches_serial_bit_for_bit() {
        // The cross-variant consistency contract extended to the parallel
        // path: exact equality across the CI thread sweep {1, 2, 8}.
        let k = Fp32Matrix::random_normal(97, 53, 1.0, 21); // odd shape
        let q = quantize_fused(&k);
        let serial = dequantize(&q);
        for threads in [1, 2, 8] {
            let mut par = Fp32Matrix::zeros(q.rows, q.cols);
            dequantize_parallel(&q, &mut par, threads);
            assert!(
                par.data.iter().zip(&serial.data).all(|(a, b)| a.to_bits() == b.to_bits()),
                "dequantize_parallel x{threads} diverged"
            );
        }
    }

    #[test]
    fn row_form_matches_matrix_form() {
        let k = Fp32Matrix::random_normal(16, 12, 1.0, 8);
        let q = quantize_fused(&k);
        let full = dequantize(&q);
        let mut row = vec![0.0f32; 12];
        dequantize_row_into(q.row(5), &q.scales, &mut row);
        assert_eq!(row, full.row(5));
    }
}
