//! Fused dequantize–attention kernels — the decode hot loop of the
//! zero-copy paged path.
//!
//! The paper's §5 argument is that INT8 KV compression buys memory
//! *bandwidth*; serving only collects that win if attention reads the
//! quantized rows **in place**, with dequantization fused into the dot
//! product, instead of materializing an FP32 copy first. These kernels do
//! exactly that over a contiguous slab of token rows (one head's slice of
//! a cache block, or a whole gathered history), in the same four
//! optimization flavors as the quantize kernels (Listings 3–8):
//!
//! * [`Variant::Naive`]      — row-outer element loop, scale loaded per
//!   element (Listing 5's access pattern).
//! * [`Variant::Tiled`]      — scales staged into a local
//!   [`TILE_DIM`]-wide tile before the row sweep (Listing 6).
//! * [`Variant::Coarsened`]  — channel-outer loop: one scale (and one
//!   query element) held in registers, amortized over all rows of the
//!   slab (Listing 7; this is the "scale hoisted out of the inner loop"
//!   form).
//! * [`Variant::Vectorized`] — chunk-of-4 channel processing with array
//!   temporaries for SIMD codegen (Listing 8).
//!
//! **Bit-stability contract.** All variants compute, for every output,
//! the *identical* float expression in the *identical* order: a score is
//! `Σ_ch q[ch] · (row[ch] as f32 · s[ch])` accumulated in ascending
//! channel order, and a value accumulation adds rows in ascending token
//! order per channel. That makes every variant bit-identical to the
//! legacy staged decode (`model::cpu_ref::decode_i8`), which is asserted
//! by `tests/parallel_consistency.rs` and the §7.5-style proptests —
//! the kernel knob can never change generated tokens.
//!
//! These are the **scalar** kernels — also the bit-identical fallback of
//! the runtime-dispatched SIMD backend ([`super::simd`], the
//! `kernel_backend` knob). The serial-order contract above is exactly
//! what stops the autovectorizer from using packed sums here; the
//! explicit AVX2/NEON kernels lift it (per-backend contract in the
//! `simd` module docs).

use super::quantize::TILE_DIM;
use super::Variant;

/// Fused dequant·dot of one query against one quantized row:
/// `Σ_ch q[ch] · (row[ch] · s[ch])`, accumulated in channel order.
#[inline]
pub fn dot_i8(variant: Variant, q: &[f32], row: &[i8], scales: &[f32]) -> f32 {
    let mut out = [0.0f32];
    dot_rows_i8(variant, q, row, scales, &mut out);
    out[0]
}

/// Fused dequant·dot of `q` against `out.len()` consecutive token rows
/// stored contiguously in `blk` (`out.len() × q.len()` int8 values):
/// `out[r] = Σ_ch q[ch] · (blk[r·d + ch] · s[ch])`.
///
/// `blk` is read in place — no dequantized copy is materialized. All
/// variants are bit-identical (module docs). `#[inline]` so the codec
/// layer's dyn dispatch doesn't block inlining of the inner loops.
#[inline]
pub fn dot_rows_i8(variant: Variant, q: &[f32], blk: &[i8], scales: &[f32], out: &mut [f32]) {
    let d = q.len();
    let rows = out.len();
    // Hard assert (one compare per call): the chunks_exact row walk would
    // silently truncate on a short slab where the old indexing panicked.
    assert_eq!(blk.len(), rows * d, "slab shape mismatch");
    debug_assert_eq!(scales.len(), d, "scales shape mismatch");
    match variant {
        Variant::Naive => {
            // The row slice is hoisted (one bounds check per row); the
            // scale stays a per-element load — that access pattern *is*
            // Listing 5, so the paper listing permits no further hoist.
            for (row, o) in blk.chunks_exact(d).zip(out.iter_mut()) {
                let mut acc = 0.0f32;
                for ch in 0..d {
                    acc += q[ch] * (row[ch] as f32 * scales[ch]);
                }
                *o = acc;
            }
        }
        Variant::Tiled => {
            out[..rows].fill(0.0);
            let mut s_tile = [0.0f32; TILE_DIM];
            let mut d0 = 0;
            while d0 < d {
                let w = TILE_DIM.min(d - d0);
                s_tile[..w].copy_from_slice(&scales[d0..d0 + w]);
                for r in 0..rows {
                    let row = &blk[r * d + d0..r * d + d0 + w];
                    let mut acc = out[r];
                    for i in 0..w {
                        acc += q[d0 + i] * (row[i] as f32 * s_tile[i]);
                    }
                    out[r] = acc;
                }
                d0 += w;
            }
        }
        Variant::Coarsened => {
            out[..rows].fill(0.0);
            for ch in 0..d {
                let s = scales[ch];
                let qc = q[ch];
                for r in 0..rows {
                    out[r] += qc * (blk[r * d + ch] as f32 * s);
                }
            }
        }
        Variant::Vectorized => {
            // chunks_exact slices instead of manual indexing: every
            // bounds check vanishes and the products autovectorize.
            // Serial adds keep the sum order identical to naive
            // (bit-stability contract).
            let tail = d / 4 * 4;
            for (row, o) in blk.chunks_exact(d).zip(out.iter_mut()) {
                let mut acc = 0.0f32;
                for ((r4, s4), q4) in row
                    .chunks_exact(4)
                    .zip(scales.chunks_exact(4))
                    .zip(q.chunks_exact(4))
                {
                    let vals = [r4[0] as f32, r4[1] as f32, r4[2] as f32, r4[3] as f32];
                    acc += q4[0] * (vals[0] * s4[0]);
                    acc += q4[1] * (vals[1] * s4[1]);
                    acc += q4[2] * (vals[2] * s4[2]);
                    acc += q4[3] * (vals[3] * s4[3]);
                }
                for ((&r, &s), &qv) in
                    row[tail..].iter().zip(&scales[tail..]).zip(&q[tail..])
                {
                    acc += qv * (r as f32 * s);
                }
                *o = acc;
            }
        }
    }
}

/// Fused softmax·V accumulation over a quantized slab:
/// `acc[ch] += Σ_r w[r] · (blk[r·d + ch] · s[ch])`, rows added in
/// ascending order per channel (bit-stability contract).
#[inline]
pub fn accumulate_rows_i8(
    variant: Variant,
    w: &[f32],
    blk: &[i8],
    scales: &[f32],
    acc: &mut [f32],
) {
    let d = acc.len();
    let rows = w.len();
    // Hard assert: see dot_rows_i8 (chunks_exact must not truncate).
    assert_eq!(blk.len(), rows * d, "slab shape mismatch");
    debug_assert_eq!(scales.len(), d, "scales shape mismatch");
    match variant {
        Variant::Naive => {
            for r in 0..rows {
                let row = &blk[r * d..(r + 1) * d];
                let wr = w[r];
                for ch in 0..d {
                    acc[ch] += wr * (row[ch] as f32 * scales[ch]);
                }
            }
        }
        Variant::Tiled => {
            let mut s_tile = [0.0f32; TILE_DIM];
            let mut d0 = 0;
            while d0 < d {
                let width = TILE_DIM.min(d - d0);
                s_tile[..width].copy_from_slice(&scales[d0..d0 + width]);
                for r in 0..rows {
                    let row = &blk[r * d + d0..r * d + d0 + width];
                    let wr = w[r];
                    for i in 0..width {
                        acc[d0 + i] += wr * (row[i] as f32 * s_tile[i]);
                    }
                }
                d0 += width;
            }
        }
        Variant::Coarsened => {
            for ch in 0..d {
                let s = scales[ch];
                let mut a = acc[ch];
                for r in 0..rows {
                    a += w[r] * (blk[r * d + ch] as f32 * s);
                }
                acc[ch] = a;
            }
        }
        Variant::Vectorized => {
            // chunks_exact slices (see dot_rows_i8): bounds checks gone,
            // per-channel adds independent — free to autovectorize.
            let tail = d / 4 * 4;
            for (row, &wr) in blk.chunks_exact(d).zip(w.iter()) {
                for ((a4, r4), s4) in acc
                    .chunks_exact_mut(4)
                    .zip(row.chunks_exact(4))
                    .zip(scales.chunks_exact(4))
                {
                    let vals = [r4[0] as f32, r4[1] as f32, r4[2] as f32, r4[3] as f32];
                    a4[0] += wr * (vals[0] * s4[0]);
                    a4[1] += wr * (vals[1] * s4[1]);
                    a4[2] += wr * (vals[2] * s4[2]);
                    a4[3] += wr * (vals[3] * s4[3]);
                }
                for ((a, &r), &s) in
                    acc[tail..].iter_mut().zip(&row[tail..]).zip(&scales[tail..])
                {
                    *a += wr * (r as f32 * s);
                }
            }
        }
    }
}

/// One query's slot in a fused **multi-query** pass over a shared slab.
///
/// Multi-query kernels take flat offsets into caller-owned arenas instead
/// of per-query slices, so one call can fan a single dequantization out
/// to W queries without W `&mut` borrows. For dots, `inp` locates the
/// member's `d`-channel query in the input arena and `out` its `rows`
/// scores in the output arena; for accumulations, `inp` locates the
/// member's `rows` softmax weights and `out` its `d`-channel accumulator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MqMember {
    /// Offset of this member's input vector in the input arena.
    pub inp: usize,
    /// Offset of this member's output region in the output arena.
    pub out: usize,
}

/// Fused multi-query dequant·dot: every member's query is dotted against
/// the **same** quantized slab in one pass, so each `row[ch]·s[ch]`
/// dequantization is computed once and fanned out to all W queries
/// (W× arithmetic amortization on top of the slab staying L1-hot).
///
/// **Bit-stability.** For every member this computes the identical float
/// expression in the identical order as a per-member [`dot_rows_i8`]
/// call: the fanned-out product `row[ch] as f32 · s[ch]` is rounded once
/// either way, and each member's score still accumulates channels
/// ascending. Batched decode therefore emits the same bits as the
/// per-sequence walk (asserted by this module's tests and
/// `tests/parallel_consistency.rs`).
pub fn dot_rows_i8_mq(
    variant: Variant,
    d: usize,
    q_arena: &[f32],
    blk: &[i8],
    scales: &[f32],
    members: &[MqMember],
    out_arena: &mut [f32],
) {
    assert_eq!(blk.len() % d, 0, "slab shape mismatch");
    let rows = blk.len() / d;
    debug_assert_eq!(scales.len(), d, "scales shape mismatch");
    match variant {
        Variant::Naive => {
            for m in members {
                let q = &q_arena[m.inp..m.inp + d];
                for (r, row) in blk.chunks_exact(d).enumerate() {
                    let mut acc = 0.0f32;
                    for ch in 0..d {
                        acc += q[ch] * (row[ch] as f32 * scales[ch]);
                    }
                    out_arena[m.out + r] = acc;
                }
            }
        }
        Variant::Tiled => {
            for m in members {
                out_arena[m.out..m.out + rows].fill(0.0);
            }
            let mut s_tile = [0.0f32; TILE_DIM];
            let mut d0 = 0;
            while d0 < d {
                let w = TILE_DIM.min(d - d0);
                s_tile[..w].copy_from_slice(&scales[d0..d0 + w]);
                for m in members {
                    let q = &q_arena[m.inp..m.inp + d];
                    for r in 0..rows {
                        let row = &blk[r * d + d0..r * d + d0 + w];
                        let mut acc = out_arena[m.out + r];
                        for i in 0..w {
                            acc += q[d0 + i] * (row[i] as f32 * s_tile[i]);
                        }
                        out_arena[m.out + r] = acc;
                    }
                }
                d0 += w;
            }
        }
        Variant::Coarsened => {
            // The fully amortized form: one dequantization per (row, ch),
            // fanned to every member while it sits in a register.
            for m in members {
                out_arena[m.out..m.out + rows].fill(0.0);
            }
            for ch in 0..d {
                let s = scales[ch];
                for r in 0..rows {
                    let dq = blk[r * d + ch] as f32 * s;
                    for m in members {
                        out_arena[m.out + r] += q_arena[m.inp + ch] * dq;
                    }
                }
            }
        }
        Variant::Vectorized => {
            for m in members {
                out_arena[m.out..m.out + rows].fill(0.0);
            }
            let tail = d / 4 * 4;
            for (r, row) in blk.chunks_exact(d).enumerate() {
                let mut c0 = 0;
                for (r4, s4) in row.chunks_exact(4).zip(scales.chunks_exact(4)) {
                    let dq = [
                        r4[0] as f32 * s4[0],
                        r4[1] as f32 * s4[1],
                        r4[2] as f32 * s4[2],
                        r4[3] as f32 * s4[3],
                    ];
                    for m in members {
                        let q0 = m.inp + c0;
                        let mut acc = out_arena[m.out + r];
                        acc += q_arena[q0] * dq[0];
                        acc += q_arena[q0 + 1] * dq[1];
                        acc += q_arena[q0 + 2] * dq[2];
                        acc += q_arena[q0 + 3] * dq[3];
                        out_arena[m.out + r] = acc;
                    }
                    c0 += 4;
                }
                for ch in tail..d {
                    let dq = row[ch] as f32 * scales[ch];
                    for m in members {
                        out_arena[m.out + r] += q_arena[m.inp + ch] * dq;
                    }
                }
            }
        }
    }
}

/// Fused multi-query softmax·V accumulation: every member's weights are
/// applied to the **same** quantized slab in one pass, dequantizing each
/// `(row, ch)` element once. Per member the accumulation order is
/// unchanged — rows ascending per channel — so the result is
/// bit-identical to a per-member [`accumulate_rows_i8`] call.
pub fn accumulate_rows_i8_mq(
    variant: Variant,
    d: usize,
    w_arena: &[f32],
    blk: &[i8],
    scales: &[f32],
    members: &[MqMember],
    acc_arena: &mut [f32],
) {
    assert_eq!(blk.len() % d, 0, "slab shape mismatch");
    debug_assert_eq!(scales.len(), d, "scales shape mismatch");
    match variant {
        Variant::Naive => {
            for (r, row) in blk.chunks_exact(d).enumerate() {
                for ch in 0..d {
                    let dq = row[ch] as f32 * scales[ch];
                    for m in members {
                        acc_arena[m.out + ch] += w_arena[m.inp + r] * dq;
                    }
                }
            }
        }
        Variant::Tiled => {
            let rows = blk.len() / d;
            let mut s_tile = [0.0f32; TILE_DIM];
            let mut d0 = 0;
            while d0 < d {
                let width = TILE_DIM.min(d - d0);
                s_tile[..width].copy_from_slice(&scales[d0..d0 + width]);
                for r in 0..rows {
                    let row = &blk[r * d + d0..r * d + d0 + width];
                    for i in 0..width {
                        let dq = row[i] as f32 * s_tile[i];
                        for m in members {
                            acc_arena[m.out + d0 + i] += w_arena[m.inp + r] * dq;
                        }
                    }
                }
                d0 += width;
            }
        }
        Variant::Coarsened => {
            let rows = blk.len() / d;
            for ch in 0..d {
                let s = scales[ch];
                for r in 0..rows {
                    let dq = blk[r * d + ch] as f32 * s;
                    for m in members {
                        acc_arena[m.out + ch] += w_arena[m.inp + r] * dq;
                    }
                }
            }
        }
        Variant::Vectorized => {
            let tail = d / 4 * 4;
            for (r, row) in blk.chunks_exact(d).enumerate() {
                let mut c0 = 0;
                for (r4, s4) in row.chunks_exact(4).zip(scales.chunks_exact(4)) {
                    let dq = [
                        r4[0] as f32 * s4[0],
                        r4[1] as f32 * s4[1],
                        r4[2] as f32 * s4[2],
                        r4[3] as f32 * s4[3],
                    ];
                    for m in members {
                        let wr = w_arena[m.inp + r];
                        let a0 = m.out + c0;
                        acc_arena[a0] += wr * dq[0];
                        acc_arena[a0 + 1] += wr * dq[1];
                        acc_arena[a0 + 2] += wr * dq[2];
                        acc_arena[a0 + 3] += wr * dq[3];
                    }
                    c0 += 4;
                }
                for ch in tail..d {
                    let dq = row[ch] as f32 * scales[ch];
                    for m in members {
                        acc_arena[m.out + ch] += w_arena[m.inp + r] * dq;
                    }
                }
            }
        }
    }
}

/// FP32 twin of [`dot_rows_i8_mq`]: no dequantization to amortize, so
/// the win is just the slab staying hot across the member loop.
pub fn dot_rows_f32_mq(
    d: usize,
    q_arena: &[f32],
    blk: &[f32],
    members: &[MqMember],
    out_arena: &mut [f32],
) {
    debug_assert_eq!(blk.len() % d, 0, "slab shape mismatch");
    let rows = blk.len() / d;
    for m in members {
        let (q, out) = (&q_arena[m.inp..m.inp + d], &mut out_arena[m.out..m.out + rows]);
        dot_rows_f32(q, blk, out);
    }
}

/// FP32 twin of [`accumulate_rows_i8_mq`].
pub fn accumulate_rows_f32_mq(
    d: usize,
    w_arena: &[f32],
    blk: &[f32],
    members: &[MqMember],
    acc_arena: &mut [f32],
) {
    debug_assert_eq!(blk.len() % d, 0, "slab shape mismatch");
    let rows = blk.len() / d;
    for m in members {
        let (w, acc) = (&w_arena[m.inp..m.inp + rows], &mut acc_arena[m.out..m.out + d]);
        accumulate_rows_f32(w, blk, acc);
    }
}

/// FP32 twin of [`dot_rows_i8`] (baseline cache precision — no scales,
/// no variants: there is nothing to fuse).
#[inline]
pub fn dot_rows_f32(q: &[f32], blk: &[f32], out: &mut [f32]) {
    let d = q.len();
    debug_assert_eq!(blk.len(), out.len() * d, "slab shape mismatch");
    for (r, o) in out.iter_mut().enumerate() {
        let row = &blk[r * d..(r + 1) * d];
        let mut acc = 0.0f32;
        for ch in 0..d {
            acc += q[ch] * row[ch];
        }
        *o = acc;
    }
}

/// FP32 twin of [`accumulate_rows_i8`].
#[inline]
pub fn accumulate_rows_f32(w: &[f32], blk: &[f32], acc: &mut [f32]) {
    let d = acc.len();
    debug_assert_eq!(blk.len(), w.len() * d, "slab shape mismatch");
    for (r, &wr) in w.iter().enumerate() {
        let row = &blk[r * d..(r + 1) * d];
        for ch in 0..d {
            acc[ch] += wr * row[ch];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::matrix::Fp32Matrix;
    use crate::quant::quantize::quantize_fused;
    use crate::util::rng::Rng;

    fn slab(rows: usize, d: usize, seed: u64) -> (Vec<i8>, Vec<f32>, Vec<f32>) {
        let k = Fp32Matrix::random_normal(rows, d, 1.0, seed);
        let q8 = quantize_fused(&k);
        let mut rng = Rng::new(seed ^ 0x51AB);
        let mut q = vec![0.0f32; d];
        rng.fill_uniform(&mut q, -1.0, 1.0);
        (q8.data, q8.scales, q)
    }

    #[test]
    fn all_variants_bit_identical_scores() {
        for (rows, d) in [(1usize, 1usize), (3, 5), (7, 16), (12, 33)] {
            let (blk, scales, q) = slab(rows, d, (rows * 131 + d) as u64);
            let mut base = vec![0.0f32; rows];
            dot_rows_i8(Variant::Naive, &q, &blk, &scales, &mut base);
            for v in Variant::ALL {
                let mut out = vec![7.7f32; rows]; // poisoned: must be overwritten
                dot_rows_i8(v, &q, &blk, &scales, &mut out);
                let bits = |x: &[f32]| x.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&out), bits(&base), "{v:?} diverged at {rows}x{d}");
            }
        }
    }

    #[test]
    fn all_variants_bit_identical_accumulation() {
        for (rows, d) in [(1usize, 4usize), (5, 9), (11, 32)] {
            let (blk, scales, _) = slab(rows, d, (rows * 17 + d) as u64);
            let mut rng = Rng::new(99);
            let mut w = vec![0.0f32; rows];
            rng.fill_uniform(&mut w, 0.0, 1.0);
            let mut init = vec![0.0f32; d];
            rng.fill_uniform(&mut init, -0.5, 0.5);
            let mut base = init.clone();
            accumulate_rows_i8(Variant::Naive, &w, &blk, &scales, &mut base);
            for v in Variant::ALL {
                let mut acc = init.clone();
                accumulate_rows_i8(v, &w, &blk, &scales, &mut acc);
                let bits = |x: &[f32]| x.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&acc), bits(&base), "{v:?} diverged at {rows}x{d}");
            }
        }
    }

    #[test]
    fn fused_matches_dequantize_then_dot() {
        // The fused kernel computes exactly q·(row·s): dequantizing to a
        // staging copy first and dotting gives the same bits (same
        // expression, same order) — the zero-copy path loses nothing.
        let (blk, scales, q) = slab(9, 24, 4);
        let mut fused = vec![0.0f32; 9];
        dot_rows_i8(Variant::Vectorized, &q, &blk, &scales, &mut fused);
        let mut staged = vec![0.0f32; 9 * 24];
        for r in 0..9 {
            for ch in 0..24 {
                staged[r * 24 + ch] = blk[r * 24 + ch] as f32 * scales[ch];
            }
        }
        let mut dense = vec![0.0f32; 9];
        dot_rows_f32(&q, &staged, &mut dense);
        assert_eq!(
            fused.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
            dense.iter().map(|f| f.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn mq_dot_bit_identical_to_per_member_calls() {
        // Every variant of the multi-query dot must produce, for every
        // member, exactly the bits of a per-member single-query call.
        for (rows, d, n_members) in [(1usize, 1usize, 1usize), (3, 5, 2), (7, 16, 4), (9, 33, 3)] {
            let (blk, scales, _) = slab(rows, d, (rows * 7 + d) as u64);
            let mut rng = Rng::new((rows + d + n_members) as u64);
            let mut q_arena = vec![0.0f32; n_members * d];
            rng.fill_uniform(&mut q_arena, -1.0, 1.0);
            let members: Vec<MqMember> =
                (0..n_members).map(|i| MqMember { inp: i * d, out: i * rows }).collect();
            let bits = |x: &[f32]| x.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
            for v in Variant::ALL {
                let mut out_arena = vec![7.7f32; n_members * rows]; // poisoned
                dot_rows_i8_mq(v, d, &q_arena, &blk, &scales, &members, &mut out_arena);
                for (i, m) in members.iter().enumerate() {
                    let mut want = vec![0.0f32; rows];
                    dot_rows_i8(v, &q_arena[m.inp..m.inp + d], &blk, &scales, &mut want);
                    assert_eq!(
                        bits(&out_arena[m.out..m.out + rows]),
                        bits(&want),
                        "{v:?} member {i} diverged at {rows}x{d}"
                    );
                }
            }
        }
    }

    #[test]
    fn mq_accumulate_bit_identical_to_per_member_calls() {
        for (rows, d, n_members) in [(1usize, 4usize, 1usize), (5, 9, 3), (11, 32, 4)] {
            let (blk, scales, _) = slab(rows, d, (rows * 31 + d) as u64);
            let mut rng = Rng::new((rows * d + n_members) as u64);
            let mut w_arena = vec![0.0f32; n_members * rows];
            rng.fill_uniform(&mut w_arena, 0.0, 1.0);
            let mut init = vec![0.0f32; n_members * d];
            rng.fill_uniform(&mut init, -0.5, 0.5);
            let members: Vec<MqMember> =
                (0..n_members).map(|i| MqMember { inp: i * rows, out: i * d }).collect();
            let bits = |x: &[f32]| x.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
            for v in Variant::ALL {
                let mut acc_arena = init.clone();
                accumulate_rows_i8_mq(v, d, &w_arena, &blk, &scales, &members, &mut acc_arena);
                for (i, m) in members.iter().enumerate() {
                    let mut want = init[m.out..m.out + d].to_vec();
                    accumulate_rows_i8(
                        v,
                        &w_arena[m.inp..m.inp + rows],
                        &blk,
                        &scales,
                        &mut want,
                    );
                    assert_eq!(
                        bits(&acc_arena[m.out..m.out + d]),
                        bits(&want),
                        "{v:?} member {i} diverged at {rows}x{d}"
                    );
                }
            }
        }
    }

    #[test]
    fn mq_f32_twins_bit_identical_to_per_member_calls() {
        let (rows, d, n) = (6usize, 12usize, 3usize);
        let mut rng = Rng::new(0xF32);
        let mut blk = vec![0.0f32; rows * d];
        let mut q_arena = vec![0.0f32; n * d];
        let mut w_arena = vec![0.0f32; n * rows];
        rng.fill_uniform(&mut blk, -1.0, 1.0);
        rng.fill_uniform(&mut q_arena, -1.0, 1.0);
        rng.fill_uniform(&mut w_arena, 0.0, 1.0);
        let bits = |x: &[f32]| x.iter().map(|f| f.to_bits()).collect::<Vec<_>>();

        let dot_members: Vec<MqMember> =
            (0..n).map(|i| MqMember { inp: i * d, out: i * rows }).collect();
        let mut out_arena = vec![0.0f32; n * rows];
        dot_rows_f32_mq(d, &q_arena, &blk, &dot_members, &mut out_arena);
        for m in &dot_members {
            let mut want = vec![0.0f32; rows];
            dot_rows_f32(&q_arena[m.inp..m.inp + d], &blk, &mut want);
            assert_eq!(bits(&out_arena[m.out..m.out + rows]), bits(&want));
        }

        let acc_members: Vec<MqMember> =
            (0..n).map(|i| MqMember { inp: i * rows, out: i * d }).collect();
        let mut acc_arena = vec![0.25f32; n * d];
        accumulate_rows_f32_mq(d, &w_arena, &blk, &acc_members, &mut acc_arena);
        for m in &acc_members {
            let mut want = vec![0.25f32; d];
            accumulate_rows_f32(&w_arena[m.inp..m.inp + rows], &blk, &mut want);
            assert_eq!(bits(&acc_arena[m.out..m.out + d]), bits(&want));
        }
    }

    #[test]
    fn dot_i8_hand_computed() {
        // q=[1,2], row=[10,-20], s=[0.1, 0.5] -> 1*1 + 2*(-10) = -19.
        let q = [1.0f32, 2.0];
        let row = [10i8, -20];
        let s = [0.1f32, 0.5];
        for v in Variant::ALL {
            let got = dot_i8(v, &q, &row, &s);
            assert!((got - -19.0).abs() < 1e-6, "{v:?}: {got}");
        }
    }

    #[test]
    fn f32_twins_hand_computed() {
        let q = [1.0f32, -1.0];
        let blk = [2.0f32, 3.0, 5.0, 7.0]; // two rows
        let mut out = [0.0f32; 2];
        dot_rows_f32(&q, &blk, &mut out);
        assert_eq!(out, [-1.0, -2.0]);
        let mut acc = [0.0f32; 2];
        accumulate_rows_f32(&[1.0, 2.0], &blk, &mut acc);
        assert_eq!(acc, [12.0, 17.0]);
    }
}
