//! Per-channel INT8 quantization for KV caches — the paper's core
//! algorithm, in pure Rust.
//!
//! This module serves three roles:
//!
//! 1. **CPU baseline**: [`quantize::quantize_naive`] and
//!    [`scales::compute_scales`] are faithful ports of the paper's C
//!    listings (same loop nests, same `roundf`/clamp semantics) — the
//!    denominator of every speedup figure.
//! 2. **Kernel-variant story on the CPU substrate**: the same four
//!    optimization strategies the paper explores on GPU (naive, tiled,
//!    coarsened, vectorized) are implemented as CPU variants, so Fig 1/5
//!    can show the variant ordering on this testbed alongside the
//!    XLA-executed Pallas artifacts.
//! 3. **Production cache writer**: the serving engine quantizes new K/V
//!    rows on the host via [`quantize::quantize_row_into`] (a (1, D) row
//!    is far below the size where offloading to the accelerator pays —
//!    measured in the ablation bench).
//! 4. **Fused attention reader**: [`attn`] fuses dequantization into the
//!    attention dot product and softmax·V accumulation so the zero-copy
//!    paged decode path attends directly over INT8 blocks, in the same
//!    four kernel variants (all bit-identical).
//! 5. **SIMD kernel backend**: [`simd`] adds explicit AVX2/NEON
//!    implementations of the fused attention and row encode/decode hot
//!    loops behind runtime CPU-feature dispatch (`kernel_backend` knob),
//!    with the scalar kernels above as the bit-identical fallback.
//!
//! Conventions (shared with `python/compile/kernels/ref.py`):
//! round-half-away-from-zero (`f32::round`), clamp to `[-127, 127]`,
//! zero-scale columns quantize to 0.

pub mod attn;
pub mod codec;
pub mod dequantize;
pub mod error;
pub mod int4;
pub mod matrix;
pub mod quantize;
pub mod scales;
pub mod simd;
pub mod tensorwise;

pub use attn::{accumulate_rows_i8, dot_i8, dot_rows_i8};
pub use codec::Codec;
pub use simd::{Isa, KernelBackend};
pub use dequantize::{dequantize, dequantize_into, dequantize_parallel};
pub use error::{attention_score_error, l2_error, max_abs_error, value_output_error};
pub use matrix::{Fp32Matrix, Int8Matrix};
pub use quantize::{quantize, quantize_fused, quantize_parallel, quantize_row_into};
pub use scales::compute_scales;

/// The four kernel-optimization strategies from the paper, §5.3.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Variant {
    /// One element at a time, scale loaded per element (paper Listing 5).
    Naive,
    /// Scales staged into a local block before the inner loop (Listing 6).
    Tiled,
    /// Column-major: one scale load amortized over a whole column (Listing 7).
    Coarsened,
    /// Chunk-of-4 processing encouraging SIMD codegen (Listing 8).
    Vectorized,
}

impl Variant {
    pub const ALL: [Variant; 4] =
        [Variant::Naive, Variant::Tiled, Variant::Coarsened, Variant::Vectorized];

    pub fn name(self) -> &'static str {
        match self {
            Variant::Naive => "naive",
            Variant::Tiled => "tiled",
            Variant::Coarsened => "coarsened",
            Variant::Vectorized => "vectorized",
        }
    }

    pub fn from_name(s: &str) -> Option<Variant> {
        Self::ALL.into_iter().find(|v| v.name() == s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_names_roundtrip() {
        for v in Variant::ALL {
            assert_eq!(Variant::from_name(v.name()), Some(v));
        }
        assert_eq!(Variant::from_name("bogus"), None);
    }
}
