//! INT4 quantization — the paper's §8.1 "lower bit-widths" extension.
//!
//! Symmetric per-channel 4-bit quantization: values clamp to [-7, 7]
//! (s_d = max|K[:,d]| / 7), two nibbles packed per byte → 8× compression
//! of the payload vs FP32. The ablation bench compares error and speed
//! against INT8 (expected: ~16× larger max error, same memory-bound speed).

use super::matrix::Fp32Matrix;

/// 4-bit symmetric bound.
pub const Q4MAX: f32 = 7.0;

/// Packed INT4 matrix: two values per byte, row-major, rows padded to an
/// even column count in storage.
#[derive(Debug, Clone, PartialEq)]
pub struct Int4Matrix {
    pub rows: usize,
    pub cols: usize,
    /// ceil(cols/2) bytes per row.
    pub data: Vec<u8>,
    pub scales: Vec<f32>,
}

impl Int4Matrix {
    pub fn bytes_per_row(cols: usize) -> usize {
        cols.div_ceil(2)
    }

    pub fn zeros(rows: usize, cols: usize) -> Self {
        Int4Matrix {
            rows,
            cols,
            data: vec![0; rows * Self::bytes_per_row(cols)],
            scales: vec![0.0; cols],
        }
    }

    /// Signed nibble at (t, d) in [-8, 7] (we only produce [-7, 7]).
    #[inline]
    pub fn at(&self, t: usize, d: usize) -> i8 {
        let byte = self.data[t * Self::bytes_per_row(self.cols) + d / 2];
        let nib = if d % 2 == 0 { byte & 0x0F } else { byte >> 4 };
        // sign-extend 4-bit two's complement
        ((nib << 4) as i8) >> 4
    }

    #[inline]
    fn set(&mut self, t: usize, d: usize, v: i8) {
        let idx = t * Self::bytes_per_row(self.cols) + d / 2;
        let nib = (v as u8) & 0x0F;
        if d % 2 == 0 {
            self.data[idx] = (self.data[idx] & 0xF0) | nib;
        } else {
            self.data[idx] = (self.data[idx] & 0x0F) | (nib << 4);
        }
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len() + self.scales.len() * 4
    }

    pub fn compression_ratio(&self) -> f64 {
        (self.rows * self.cols * 4) as f64 / self.size_bytes() as f64
    }
}

/// Quantize one value to a signed 4-bit code in `[-7, 7]`.
///
/// Mirrors [`crate::quant::quantize::quantize_one`]'s pinned edge-case
/// semantics (the serving cache writer routes through this): zero/negative
/// scale → 0, NaN value or NaN quotient → 0, ±∞ saturates to ±7.
#[inline(always)]
pub fn quantize_one4(val: f32, scale: f32) -> i8 {
    if scale <= 0.0 {
        return 0;
    }
    let q = (val / scale).round();
    if q.is_nan() {
        return 0;
    }
    q.clamp(-Q4MAX, Q4MAX) as i8
}

/// Quantize a row of `2·out.len()` values into packed nibbles (even
/// channel in the low nibble — the [`Int4Matrix`] convention). The row
/// length must be even; the paged INT4 cache guarantees this by requiring
/// an even `head_dim`.
#[inline]
pub fn quantize4_row_into(row: &[f32], scales: &[f32], out: &mut [u8]) {
    debug_assert_eq!(row.len() % 2, 0, "int4 rows must have even length");
    debug_assert_eq!(row.len(), scales.len());
    debug_assert_eq!(out.len() * 2, row.len());
    for (i, byte) in out.iter_mut().enumerate() {
        let lo = quantize_one4(row[2 * i], scales[2 * i]) as u8 & 0x0F;
        let hi = quantize_one4(row[2 * i + 1], scales[2 * i + 1]) as u8 & 0x0F;
        *byte = lo | (hi << 4);
    }
}

/// Unpack + dequantize a nibble-packed row (`bytes.len()·2` values) into
/// `out` — the per-block read primitive of the paged INT4 decode path.
#[inline]
pub fn dequantize4_row_into(bytes: &[u8], scales: &[f32], out: &mut [f32]) {
    debug_assert_eq!(bytes.len() * 2, out.len());
    debug_assert_eq!(scales.len(), out.len());
    for (i, &byte) in bytes.iter().enumerate() {
        // sign-extend each 4-bit two's-complement nibble
        let lo = ((byte << 4) as i8) >> 4;
        let hi = (byte as i8) >> 4;
        out[2 * i] = lo as f32 * scales[2 * i];
        out[2 * i + 1] = hi as f32 * scales[2 * i + 1];
    }
}

/// Per-channel INT4 scales: s_d = max_t |K[t,d]| / 7.
pub fn compute_scales4(k: &Fp32Matrix) -> Vec<f32> {
    let mut maxima = vec![0.0f32; k.cols];
    for t in 0..k.rows {
        for (m, v) in maxima.iter_mut().zip(k.row(t)) {
            let a = v.abs();
            if a > *m {
                *m = a;
            }
        }
    }
    maxima.iter().map(|m| m / Q4MAX).collect()
}

pub fn quantize4(k: &Fp32Matrix) -> Int4Matrix {
    let scales = compute_scales4(k);
    let mut out = Int4Matrix::zeros(k.rows, k.cols);
    for t in 0..k.rows {
        for d in 0..k.cols {
            out.set(t, d, quantize_one4(k.at(t, d), scales[d]));
        }
    }
    out.scales = scales;
    out
}

pub fn dequantize4(q: &Int4Matrix) -> Fp32Matrix {
    let mut out = Fp32Matrix::zeros(q.rows, q.cols);
    for t in 0..q.rows {
        for d in 0..q.cols {
            out.data[t * q.cols + d] = q.at(t, d) as f32 * q.scales[d];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::error::max_abs_error;

    #[test]
    fn nibble_roundtrip_all_values() {
        let mut m = Int4Matrix::zeros(1, 15);
        for (d, v) in (-7..=7).enumerate() {
            m.set(0, d, v);
        }
        for (d, v) in (-7..=7).enumerate() {
            assert_eq!(m.at(0, d), v, "nibble {d}");
        }
    }

    #[test]
    fn error_bounded_by_half_scale() {
        let k = Fp32Matrix::random_uniform(128, 32, -1.0, 1.0, 4);
        let q = quantize4(&k);
        let r = dequantize4(&q);
        for t in 0..k.rows {
            for d in 0..k.cols {
                let err = (k.at(t, d) - r.at(t, d)).abs();
                assert!(err <= q.scales[d] / 2.0 + 1e-6);
            }
        }
    }

    #[test]
    fn int4_error_roughly_16x_int8() {
        use crate::quant::{dequantize::dequantize, quantize::quantize_fused};
        let k = Fp32Matrix::random_uniform(2048, 64, -1.0, 1.0, 5);
        let e8 = max_abs_error(&k, &dequantize(&quantize_fused(&k)));
        let e4 = max_abs_error(&k, &dequantize4(&quantize4(&k)));
        let ratio = e4 / e8;
        // 1/(2·7) vs 1/(2·127): ratio ≈ 18.1 in the saturated-max limit.
        assert!(ratio > 10.0 && ratio < 25.0, "ratio {ratio}");
    }

    #[test]
    fn compression_approaches_8x() {
        let q = Int4Matrix::zeros(131072, 1024);
        let r = q.compression_ratio();
        assert!(r > 7.9 && r <= 8.0, "ratio {r}");
    }

    #[test]
    fn odd_column_count_packs() {
        let k = Fp32Matrix::random_uniform(4, 5, -1.0, 1.0, 6);
        let q = quantize4(&k);
        assert_eq!(q.data.len(), 4 * 3);
        let r = dequantize4(&q);
        assert_eq!(r.cols, 5);
        assert!(max_abs_error(&k, &r) <= 1.0 / 14.0 + 1e-6);
    }

    #[test]
    fn single_column_matrix_packs_one_nibble_per_row() {
        // cols = 1: each row occupies one byte with only the low nibble
        // used; negative values must sign-extend correctly.
        let k = Fp32Matrix::from_vec(3, 1, vec![1.0, -1.0, 0.5]);
        let q = quantize4(&k);
        assert_eq!(Int4Matrix::bytes_per_row(1), 1);
        assert_eq!(q.data.len(), 3);
        assert_eq!(q.at(0, 0), 7);
        assert_eq!(q.at(1, 0), -7);
        assert_eq!(q.at(2, 0), 4, "0.5/(1/7) = 3.5 rounds half-away to 4");
        // The unused high nibble of each byte stays clear.
        assert!(q.data.iter().all(|&b| b >> 4 == 0), "padding nibble written");
    }

    #[test]
    fn odd_tail_nibble_isolated_from_neighbors() {
        // cols = 7: the last (odd) nibble of each row shares no byte with
        // the next row; writing extreme values at the tail must not bleed.
        let mut m = Int4Matrix::zeros(2, 7);
        m.set(0, 6, -7);
        m.set(1, 0, 7);
        assert_eq!(m.at(0, 6), -7);
        assert_eq!(m.at(1, 0), 7);
        // Everything else still zero.
        for t in 0..2 {
            for d in 0..7 {
                if (t, d) != (0, 6) && (t, d) != (1, 0) {
                    assert_eq!(m.at(t, d), 0, "bleed at ({t},{d})");
                }
            }
        }
        // Overwriting a low nibble preserves its high-nibble neighbor.
        m.set(0, 5, 3);
        assert_eq!(m.at(0, 6), -7);
        assert_eq!(m.at(0, 5), 3);
    }

    #[test]
    fn exhaustive_pack_unpack_odd_widths() {
        // Every (row, col) position round-trips every representable value
        // for a sweep of odd column counts.
        for cols in [1usize, 3, 5, 9] {
            let mut m = Int4Matrix::zeros(2, cols);
            for t in 0..2 {
                for d in 0..cols {
                    for v in -7i8..=7 {
                        m.set(t, d, v);
                        assert_eq!(m.at(t, d), v, "cols={cols} ({t},{d}) value {v}");
                    }
                }
            }
        }
    }

    #[test]
    fn odd_width_quantize_matches_per_element_reference() {
        let k = Fp32Matrix::random_uniform(9, 7, -2.0, 2.0, 0x0DD);
        let q = quantize4(&k);
        let s = compute_scales4(&k);
        for t in 0..k.rows {
            for d in 0..k.cols {
                let expect = if s[d] <= 0.0 {
                    0
                } else {
                    (k.at(t, d) / s[d]).round().clamp(-Q4MAX, Q4MAX) as i8
                };
                assert_eq!(q.at(t, d), expect, "({t},{d})");
            }
        }
    }

    #[test]
    fn row_pack_unpack_roundtrips_against_matrix_form() {
        // The serving row helpers must agree exactly with the matrix-form
        // quantize4/dequantize4 (same nibble convention, same rounding).
        let d = 10;
        let k = Fp32Matrix::random_uniform(4, d, -2.0, 2.0, 0x40);
        let q = quantize4(&k);
        for t in 0..k.rows {
            let mut packed = vec![0u8; d / 2];
            quantize4_row_into(k.row(t), &q.scales, &mut packed);
            assert_eq!(
                packed,
                q.data[t * Int4Matrix::bytes_per_row(d)..(t + 1) * Int4Matrix::bytes_per_row(d)],
                "row {t} packed bytes diverged"
            );
            let mut unpacked = vec![0.0f32; d];
            dequantize4_row_into(&packed, &q.scales, &mut unpacked);
            let reference = dequantize4(&q);
            for ch in 0..d {
                assert_eq!(unpacked[ch].to_bits(), reference.at(t, ch).to_bits());
            }
        }
    }

    #[test]
    fn quantize_one4_edge_cases() {
        assert_eq!(quantize_one4(0.5, 1.0), 1, "half rounds away from zero");
        assert_eq!(quantize_one4(1e9, 1.0), 7);
        assert_eq!(quantize_one4(-1e9, 1.0), -7);
        assert_eq!(quantize_one4(f32::INFINITY, 1.0), 7);
        assert_eq!(quantize_one4(1.0, 0.0), 0);
        assert_eq!(quantize_one4(f32::NAN, 1.0), 0);
        assert_eq!(quantize_one4(1.0, f32::NAN), 0);
    }

    #[test]
    fn zeros_quantize_to_zeros() {
        let k = Fp32Matrix::zeros(4, 4);
        let q = quantize4(&k);
        assert!(q.data.iter().all(|&b| b == 0));
    }
}
