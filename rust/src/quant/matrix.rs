//! Matrix containers — the paper's §5.1 data structures.
//!
//! Row-major dense matrices: `Fp32Matrix` holds the original K/V data,
//! `Int8Matrix` holds the quantized payload plus its per-channel scales
//! (D f32 values — negligible next to T×D payload, eq. 5 discussion).

use crate::util::rng::Rng;

/// Dense row-major FP32 matrix of shape (rows=T, cols=D).
#[derive(Debug, Clone, PartialEq)]
pub struct Fp32Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Fp32Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Fp32Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Fp32Matrix { rows, cols, data }
    }

    /// Seeded U(lo, hi) fill — the paper's randomized test matrices.
    pub fn random_uniform(rows: usize, cols: usize, lo: f32, hi: f32, seed: u64) -> Self {
        let mut m = Self::zeros(rows, cols);
        Rng::new(seed).fill_uniform(&mut m.data, lo, hi);
        m
    }

    /// Seeded N(0, sigma) fill.
    pub fn random_normal(rows: usize, cols: usize, sigma: f32, seed: u64) -> Self {
        let mut m = Self::zeros(rows, cols);
        Rng::new(seed).fill_normal(&mut m.data, sigma);
        m
    }

    #[inline]
    pub fn at(&self, t: usize, d: usize) -> f32 {
        self.data[t * self.cols + d]
    }

    #[inline]
    pub fn row(&self, t: usize) -> &[f32] {
        &self.data[t * self.cols..(t + 1) * self.cols]
    }

    pub fn elements(&self) -> usize {
        self.rows * self.cols
    }

    pub fn size_bytes(&self) -> usize {
        self.elements() * std::mem::size_of::<f32>()
    }
}

/// Quantized INT8 matrix + per-channel scales. 4x smaller payload than the
/// FP32 original (§5.1: "The quantized matrix uses 4× less memory").
#[derive(Debug, Clone, PartialEq)]
pub struct Int8Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<i8>,
    /// Per-channel scales, one per column (eq. 5).
    pub scales: Vec<f32>,
}

impl Int8Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Int8Matrix { rows, cols, data: vec![0; rows * cols], scales: vec![0.0; cols] }
    }

    #[inline]
    pub fn at(&self, t: usize, d: usize) -> i8 {
        self.data[t * self.cols + d]
    }

    #[inline]
    pub fn row(&self, t: usize) -> &[i8] {
        &self.data[t * self.cols..(t + 1) * self.cols]
    }

    pub fn elements(&self) -> usize {
        self.rows * self.cols
    }

    /// Payload + scales, in bytes.
    pub fn size_bytes(&self) -> usize {
        self.elements() + self.scales.len() * std::mem::size_of::<f32>()
    }

    /// Memory saving vs the FP32 original (≈4x for realistic shapes).
    pub fn compression_ratio(&self) -> f64 {
        (self.elements() * 4) as f64 / self.size_bytes() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_shapes() {
        let m = Fp32Matrix::zeros(3, 5);
        assert_eq!((m.rows, m.cols, m.data.len()), (3, 5, 15));
        let q = Int8Matrix::zeros(3, 5);
        assert_eq!(q.scales.len(), 5);
    }

    #[test]
    fn random_fill_within_bounds() {
        // Paper §7.5: "Randomized fill routines are validated to ensure
        // values remain within specified bounds."
        let m = Fp32Matrix::random_uniform(64, 32, -1.0, 1.0, 7);
        assert!(m.data.iter().all(|v| (-1.0..1.0).contains(v)));
        // Deterministic per seed.
        let m2 = Fp32Matrix::random_uniform(64, 32, -1.0, 1.0, 7);
        assert_eq!(m.data, m2.data);
    }

    #[test]
    fn indexing_is_row_major() {
        let m = Fp32Matrix::from_vec(2, 3, vec![0., 1., 2., 3., 4., 5.]);
        assert_eq!(m.at(0, 2), 2.0);
        assert_eq!(m.at(1, 0), 3.0);
        assert_eq!(m.row(1), &[3., 4., 5.]);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn from_vec_validates() {
        Fp32Matrix::from_vec(2, 2, vec![0.0; 3]);
    }

    #[test]
    fn compression_ratio_approaches_4x() {
        let q = Int8Matrix::zeros(131072, 1024);
        let r = q.compression_ratio();
        assert!(r > 3.99 && r <= 4.0, "ratio {r}");
        // Tiny matrices amortize scales poorly.
        let q = Int8Matrix::zeros(1, 8);
        assert!(q.compression_ratio() < 1.0);
    }

    #[test]
    fn size_bytes_counts_scales() {
        let q = Int8Matrix::zeros(10, 4);
        assert_eq!(q.size_bytes(), 40 + 16);
    }
}
