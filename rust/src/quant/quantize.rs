//! Quantization — eq. (7) — in the paper's four kernel-optimization
//! flavors, as CPU implementations (DESIGN.md §Hardware-Adaptation maps
//! these to the Pallas BlockSpec variants executed via PJRT).
//!
//! All variants produce **identical** outputs (asserted by tests and by
//! the paper's §7.5 cross-kernel consistency check); they differ only in
//! memory-access structure:
//!
//! * `quantize_naive`      — element loop, scale indexed per element
//!   (faithful port of Listing 3 / Listing 5's access pattern).
//! * `quantize_tiled`      — scales staged into a fixed local tile before
//!   the inner loop (shared-memory analog of Listing 6).
//! * `quantize_coarsened`  — column-outer loop, one scale register per
//!   column amortized over T elements (Listing 7).
//! * `quantize_vectorized` — chunk-of-4 row processing structured for
//!   SIMD codegen (float4/char4 analog of Listing 8).
//! * `quantize_parallel`   — row-partitioned multi-threaded vectorized.

use super::matrix::{Fp32Matrix, Int8Matrix};
use super::scales;
use super::Variant;
use crate::parallel::{self, SendPtr};
use crate::QMAX;

/// Quantize one value: round-half-away (f32::round), clamp, zero-scale → 0.
///
/// Non-finite handling is pinned (see `nan_inputs_quantize_to_zero`):
/// a NaN value — or a NaN scale, for which the `<= 0.0` guard is false —
/// produces a NaN quotient, which maps to 0 rather than flowing through
/// `clamp` into an unspecified-looking `as` cast. ±∞ saturates to ±127.
#[inline(always)]
pub fn quantize_one(val: f32, scale: f32) -> i8 {
    if scale <= 0.0 {
        return 0;
    }
    let q = (val / scale).round();
    if q.is_nan() {
        return 0;
    }
    q.clamp(-QMAX, QMAX) as i8
}

/// Paper Listing 3: row-outer, column-inner, scale loaded per element.
pub fn quantize_naive(k: &Fp32Matrix, scales: &[f32], out: &mut Int8Matrix) {
    check_shapes(k, scales, out);
    for t in 0..k.rows {
        for d in 0..k.cols {
            let val = k.data[t * k.cols + d];
            out.data[t * k.cols + d] = quantize_one(val, scales[d]);
        }
    }
    out.scales.copy_from_slice(scales);
}

/// Paper-methodology CPU baseline: the same Listing-3 loop nest with
/// per-element volatile loads/stores, which forbids the autovectorization
/// rustc would otherwise apply.
///
/// Why this exists: the paper's CPU column (79 s for 1B elements ≈ 74
/// ns/element) is only reachable by an *unoptimized* scalar build — an
/// -O3 C/Rust loop runs this memory-bound kernel ~30-50× faster. To
/// reproduce Figure 1's methodology we need a comparable denominator;
/// `quantize_naive` (which rustc vectorizes) is reported alongside as the
/// honest optimized-CPU reference. See EXPERIMENTS.md Fig-1 discussion.
pub fn quantize_naive_unopt(k: &Fp32Matrix, scales: &[f32], out: &mut Int8Matrix) {
    check_shapes(k, scales, out);
    for t in 0..k.rows {
        for d in 0..k.cols {
            // SAFETY: indices are in bounds by the loop ranges; volatile
            // is used purely as an optimization barrier.
            unsafe {
                let val = std::ptr::read_volatile(k.data.as_ptr().add(t * k.cols + d));
                let s = std::ptr::read_volatile(scales.as_ptr().add(d));
                let q = quantize_one(val, s);
                std::ptr::write_volatile(out.data.as_mut_ptr().add(t * k.cols + d), q);
            }
        }
    }
    out.scales.copy_from_slice(scales);
}

/// Tile width for the scale-staging variant (mirrors TILE_DIM in Listing 6).
pub const TILE_DIM: usize = 32;

/// Listing 6 analog: copy a TILE_DIM-wide strip of scales into a local
/// buffer, then sweep all rows of that strip reusing the staged scales.
pub fn quantize_tiled(k: &Fp32Matrix, scales: &[f32], out: &mut Int8Matrix) {
    check_shapes(k, scales, out);
    let mut s_tile = [0.0f32; TILE_DIM];
    let mut d0 = 0;
    while d0 < k.cols {
        let w = TILE_DIM.min(k.cols - d0);
        s_tile[..w].copy_from_slice(&scales[d0..d0 + w]);
        for t in 0..k.rows {
            let base = t * k.cols + d0;
            for i in 0..w {
                out.data[base + i] = quantize_one(k.data[base + i], s_tile[i]);
            }
        }
        d0 += w;
    }
    out.scales.copy_from_slice(scales);
}

/// Listing 7 analog: column-outer loop; one scale held in a register for
/// the whole column (strided T-element walk).
pub fn quantize_coarsened(k: &Fp32Matrix, scales: &[f32], out: &mut Int8Matrix) {
    check_shapes(k, scales, out);
    for d in 0..k.cols {
        let s = scales[d];
        for t in 0..k.rows {
            let idx = t * k.cols + d;
            out.data[idx] = quantize_one(k.data[idx], s);
        }
    }
    out.scales.copy_from_slice(scales);
}

/// Listing 8 analog: process rows in chunks of 4 with array temporaries so
/// the autovectorizer emits SIMD loads/divides/stores; remainder handled
/// scalar (the paper's "requires D divisible by 4" caveat, fixed).
pub fn quantize_vectorized(k: &Fp32Matrix, scales: &[f32], out: &mut Int8Matrix) {
    check_shapes(k, scales, out);
    for t in 0..k.rows {
        let row_in = &k.data[t * k.cols..(t + 1) * k.cols];
        let row_out = &mut out.data[t * k.cols..(t + 1) * k.cols];
        quantize_row_into(row_in, scales, row_out);
    }
    out.scales.copy_from_slice(scales);
}

/// Vectorized quantization of a single row — also the serving engine's
/// cache-writer hot path (new K/V rows are quantized host-side).
///
/// chunks_exact slices instead of manual indexing: the bounds checks
/// vanish and the chunk body autovectorizes; quantize_one's zero-scale
/// guard compiles to a select. Bit-identical to the pre-rewrite loop
/// (same `quantize_one` call per element).
#[inline]
pub fn quantize_row_into(row: &[f32], scales: &[f32], out: &mut [i8]) {
    let n = row.len();
    // Hard assert (one compare per row): the chunks_exact walk would
    // silently truncate on a short `out` where indexing used to panic.
    assert_eq!(out.len(), n, "row/out length mismatch");
    debug_assert_eq!(scales.len(), n, "row/scales length mismatch");
    let tail = n / 4 * 4;
    for ((o4, r4), s4) in out
        .chunks_exact_mut(4)
        .zip(row.chunks_exact(4))
        .zip(scales.chunks_exact(4))
    {
        o4[0] = quantize_one(r4[0], s4[0]);
        o4[1] = quantize_one(r4[1], s4[1]);
        o4[2] = quantize_one(r4[2], s4[2]);
        o4[3] = quantize_one(r4[3], s4[3]);
    }
    for ((o, &r), &s) in out[tail..].iter_mut().zip(&row[tail..]).zip(&scales[tail..]) {
        *o = quantize_one(r, s);
    }
}

/// Row chunk granularity for the parallel quantize/dequantize paths.
pub(crate) const ROW_CHUNK: usize = 256;

/// Multi-threaded vectorized quantization, row-partitioned through the
/// shared [`crate::parallel`] runtime. Bit-identical to the serial
/// variants at any thread count (each element is quantized by the same
/// `quantize_one` call; workers own disjoint rows).
pub fn quantize_parallel(k: &Fp32Matrix, scales: &[f32], out: &mut Int8Matrix, threads: usize) {
    check_shapes(k, scales, out);
    let cols = k.cols;
    let out_ptr = SendPtr::new(out.data.as_mut_ptr());
    parallel::parallel_chunks(k.rows, ROW_CHUNK, threads, |lo, hi| {
        for t in lo..hi {
            let row_in = &k.data[t * cols..(t + 1) * cols];
            // SAFETY: row ranges [lo, hi) are disjoint across workers, so
            // the mutable row slices never overlap.
            let row_out = unsafe { std::slice::from_raw_parts_mut(out_ptr.add(t * cols), cols) };
            quantize_row_into(row_in, scales, row_out);
        }
    });
    out.scales.copy_from_slice(scales);
}

/// Dispatch by [`Variant`].
pub fn quantize_variant(v: Variant, k: &Fp32Matrix, scales: &[f32], out: &mut Int8Matrix) {
    match v {
        Variant::Naive => quantize_naive(k, scales, out),
        Variant::Tiled => quantize_tiled(k, scales, out),
        Variant::Coarsened => quantize_coarsened(k, scales, out),
        Variant::Vectorized => quantize_vectorized(k, scales, out),
    }
}

/// Scales + quantize in one call (two passes, cache-blocked by column
/// strips so the strip stays resident between the passes).
pub fn quantize_fused(k: &Fp32Matrix) -> Int8Matrix {
    let mut out = Int8Matrix::zeros(k.rows, k.cols);
    let s = scales::compute_scales(k);
    quantize_vectorized(k, &s, &mut out);
    out
}

/// Convenience: compute scales then quantize with the given variant.
pub fn quantize(k: &Fp32Matrix, v: Variant) -> Int8Matrix {
    let s = scales::compute_scales(k);
    let mut out = Int8Matrix::zeros(k.rows, k.cols);
    quantize_variant(v, k, &s, &mut out);
    out
}

fn check_shapes(k: &Fp32Matrix, scales: &[f32], out: &Int8Matrix) {
    assert_eq!(scales.len(), k.cols, "scales/cols mismatch");
    assert_eq!((out.rows, out.cols), (k.rows, k.cols), "out shape mismatch");
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(seed: u64) -> (Fp32Matrix, Vec<f32>) {
        let k = Fp32Matrix::random_normal(97, 53, 1.0, seed); // odd shape
        let s = scales::compute_scales(&k);
        (k, s)
    }

    #[test]
    fn rounding_is_half_away_from_zero() {
        assert_eq!(quantize_one(0.5, 1.0), 1);
        assert_eq!(quantize_one(-0.5, 1.0), -1);
        assert_eq!(quantize_one(1.5, 1.0), 2);
        assert_eq!(quantize_one(-1.5, 1.0), -2);
        assert_eq!(quantize_one(0.49, 1.0), 0);
    }

    #[test]
    fn clamping() {
        assert_eq!(quantize_one(1e9, 1.0), 127);
        assert_eq!(quantize_one(-1e9, 1.0), -127);
        assert_eq!(quantize_one(f32::INFINITY, 1.0), 127);
        assert_eq!(quantize_one(f32::NEG_INFINITY, 1.0), -127);
    }

    #[test]
    fn zero_scale_quantizes_to_zero() {
        assert_eq!(quantize_one(123.0, 0.0), 0);
        assert_eq!(quantize_one(123.0, -1.0), 0);
    }

    #[test]
    fn nan_inputs_quantize_to_zero() {
        // Pinned behavior (latent-bug audit): NaN must not flow through
        // round().clamp() into the final cast — it maps to 0, on every
        // variant, at every thread count.
        assert_eq!(quantize_one(f32::NAN, 1.0), 0);
        assert_eq!(quantize_one(-f32::NAN, 1.0), 0);
        assert_eq!(quantize_one(1.0, f32::NAN), 0);
        assert_eq!(quantize_one(f32::NAN, f32::NAN), 0);
        // Infinities still saturate.
        assert_eq!(quantize_one(f32::INFINITY, 2.0), 127);
        assert_eq!(quantize_one(f32::NEG_INFINITY, 2.0), -127);

        let mut k = Fp32Matrix::random_uniform(33, 9, -1.0, 1.0, 77);
        k.data[5] = f32::NAN;
        k.data[40] = f32::NAN;
        let s = scales::compute_scales(&k);
        assert!(s.iter().all(|v| v.is_finite()), "NaN must not poison scales");
        let mut base = Int8Matrix::zeros(k.rows, k.cols);
        quantize_naive(&k, &s, &mut base);
        assert_eq!(base.data[5], 0);
        assert_eq!(base.data[40], 0);
        for v in Variant::ALL {
            let mut out = Int8Matrix::zeros(k.rows, k.cols);
            quantize_variant(v, &k, &s, &mut out);
            assert_eq!(out.data, base.data, "variant {v:?} diverged on NaN input");
        }
        for threads in [1, 2, 8] {
            let mut par = Int8Matrix::zeros(k.rows, k.cols);
            quantize_parallel(&k, &s, &mut par, threads);
            assert_eq!(par.data, base.data, "parallel x{threads} diverged on NaN input");
        }
    }

    #[test]
    fn all_variants_identical() {
        // Paper §7.5 cross-kernel consistency, plus the parallel variant
        // across the CI thread sweep {1, 2, 8}.
        let (k, s) = sample(5);
        let mut base = Int8Matrix::zeros(k.rows, k.cols);
        quantize_naive(&k, &s, &mut base);
        for v in [Variant::Tiled, Variant::Coarsened, Variant::Vectorized] {
            let mut out = Int8Matrix::zeros(k.rows, k.cols);
            quantize_variant(v, &k, &s, &mut out);
            assert_eq!(out.data, base.data, "variant {:?}", v);
        }
        for threads in [1, 2, 8] {
            let mut par = Int8Matrix::zeros(k.rows, k.cols);
            quantize_parallel(&k, &s, &mut par, threads);
            assert_eq!(par.data, base.data, "parallel x{threads} diverged");
        }
    }

    #[test]
    fn hand_constructed_values() {
        // K = [[1, -2], [0.5, 2]], col maxima [1, 2] -> scales [1/127, 2/127]
        let k = Fp32Matrix::from_vec(2, 2, vec![1.0, -2.0, 0.5, 2.0]);
        let q = quantize_fused(&k);
        assert_eq!(q.data, vec![127, -127, 64, 127]); // 0.5/(1/127)=63.5 -> 64
    }

    #[test]
    fn abs_max_never_overflows() {
        // Values exactly at the column max hit ±127 exactly.
        let (k, s) = sample(11);
        let q = quantize_fused(&k);
        assert!(q.data.iter().all(|&v| (-127..=127).contains(&(v as i32))));
        let _ = s;
    }

    #[test]
    fn remainder_columns_handled() {
        // cols=5: one chunk of 4 + remainder 1.
        let k = Fp32Matrix::random_uniform(3, 5, -1.0, 1.0, 9);
        let s = scales::compute_scales(&k);
        let mut a = Int8Matrix::zeros(3, 5);
        let mut b = Int8Matrix::zeros(3, 5);
        quantize_naive(&k, &s, &mut a);
        quantize_vectorized(&k, &s, &mut b);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn one_by_one_matrix() {
        let k = Fp32Matrix::from_vec(1, 1, vec![0.5]);
        let q = quantize_fused(&k);
        assert_eq!(q.data, vec![127]);
    }

    #[test]
    #[should_panic(expected = "scales/cols mismatch")]
    fn shape_validation() {
        let k = Fp32Matrix::zeros(2, 3);
        let mut out = Int8Matrix::zeros(2, 3);
        quantize_naive(&k, &[0.0; 2], &mut out);
    }
}
