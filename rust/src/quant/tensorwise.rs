//! Per-tensor (single global scale) quantization — the ablation baseline
//! that motivates the paper's per-channel choice (§3.3: "improving
//! precision compared to a single global scale").

use super::matrix::{Fp32Matrix, Int8Matrix};
use crate::QMAX;

/// Single global scale: s = max|K| / 127 (stored replicated across the
/// scales vector so `Int8Matrix` consumers work unchanged).
pub fn quantize_tensorwise(k: &Fp32Matrix) -> Int8Matrix {
    let mut max_abs = 0.0f32;
    for v in &k.data {
        let a = v.abs();
        if a > max_abs {
            max_abs = a;
        }
    }
    let s = max_abs / QMAX;
    let mut out = Int8Matrix::zeros(k.rows, k.cols);
    if s > 0.0 {
        for (o, &v) in out.data.iter_mut().zip(&k.data) {
            *o = (v / s).round().clamp(-QMAX, QMAX) as i8;
        }
    }
    out.scales.fill(s);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::dequantize::dequantize;
    use crate::quant::error::max_abs_error;
    use crate::quant::quantize::quantize_fused;

    #[test]
    fn uniform_scale_replicated() {
        let k = Fp32Matrix::random_uniform(32, 8, -2.0, 2.0, 1);
        let q = quantize_tensorwise(&k);
        assert!(q.scales.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn per_channel_wins_on_mixed_ranges() {
        // One hot column inflates the global scale; per-channel shrugs.
        let mut k = Fp32Matrix::random_uniform(256, 16, -1.0, 1.0, 2);
        for t in 0..k.rows {
            k.data[t * k.cols] *= 100.0;
        }
        let pc = dequantize(&quantize_fused(&k));
        let pt = dequantize(&quantize_tensorwise(&k));
        // Compare error on the *normal* columns only.
        let mut err_pc = 0.0f64;
        let mut err_pt = 0.0f64;
        for t in 0..k.rows {
            for d in 1..k.cols {
                err_pc = err_pc.max((k.at(t, d) - pc.at(t, d)).abs() as f64);
                err_pt = err_pt.max((k.at(t, d) - pt.at(t, d)).abs() as f64);
            }
        }
        assert!(err_pc * 10.0 < err_pt, "pc {err_pc} vs pt {err_pt}");
    }

    #[test]
    fn equal_ranges_match_per_channel_bound() {
        // With homogeneous columns the two schemes are equivalent-ish.
        let k = Fp32Matrix::random_uniform(512, 32, -1.0, 1.0, 3);
        let pt = dequantize(&quantize_tensorwise(&k));
        assert!(max_abs_error(&k, &pt) <= 1.0 / 254.0 + 1e-6);
    }

    #[test]
    fn zero_matrix_safe() {
        let k = Fp32Matrix::zeros(4, 4);
        let q = quantize_tensorwise(&k);
        assert!(q.data.iter().all(|&v| v == 0));
        assert!(q.scales.iter().all(|&s| s == 0.0));
    }
}
