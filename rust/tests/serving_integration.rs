//! Coordinator integration: router → engine → cache → backend, using the
//! CPU oracle backend (no artifacts needed — runs everywhere).

use kvq::coordinator::batcher::BatcherConfig;
use kvq::coordinator::engine::{self, EngineConfig};
use kvq::coordinator::request::{collect_response, FinishReason};
use kvq::coordinator::router::{RoutePolicy, Router};
use kvq::kvcache::{PolicySpec, Precision};
use kvq::model::runner::CpuBackend;
use kvq::model::sample::SamplingParams;
use kvq::model::weights::Weights;
use kvq::model::ModelSpec;

fn cpu_factory() -> impl FnOnce() -> anyhow::Result<Box<dyn kvq::model::LmBackend>> + Send {
    || {
        let spec = ModelSpec::test_tiny();
        let w = Weights::synthetic(&spec, 7);
        Ok(Box::new(CpuBackend::new(spec, w)) as Box<dyn kvq::model::LmBackend>)
    }
}

fn default_engine(precision: Precision) -> EngineConfig {
    EngineConfig { quant_policy: PolicySpec::uniform(precision), ..Default::default() }
}

fn policy_engine(policy: PolicySpec) -> EngineConfig {
    EngineConfig { quant_policy: policy, ..Default::default() }
}

#[test]
fn single_request_generates_exact_token_count() {
    let (h, join) = engine::spawn(default_engine(Precision::Int8), cpu_factory());
    let mut router = Router::new(RoutePolicy::RoundRobin);
    router.add_engine("int8", h.clone());

    let (_, rx) = router.submit(vec![10, 20, 30], 5, SamplingParams::default()).unwrap();
    let (tokens, reason, ttft, elapsed) = collect_response(&rx);
    assert_eq!(tokens.len(), 5);
    assert_eq!(reason, FinishReason::Length);
    assert!(ttft > 0.0 && elapsed >= ttft);

    h.drain();
    join.join().unwrap();
    let m = h.metrics.snapshot();
    assert_eq!(m.requests_finished, 1);
    assert_eq!(m.tokens_generated, 5);
}

#[test]
fn greedy_generation_is_deterministic_across_requests() {
    let (h, join) = engine::spawn(default_engine(Precision::Int8), cpu_factory());
    let mut router = Router::new(RoutePolicy::RoundRobin);
    router.add_engine("int8", h.clone());

    let prompt = vec![1, 2, 3, 4];
    let (_, rx1) = router.submit(prompt.clone(), 6, SamplingParams::default()).unwrap();
    let (t1, ..) = collect_response(&rx1);
    let (_, rx2) = router.submit(prompt, 6, SamplingParams::default()).unwrap();
    let (t2, ..) = collect_response(&rx2);
    assert_eq!(t1, t2, "greedy must be reproducible");

    h.drain();
    join.join().unwrap();
}

#[test]
fn concurrent_requests_all_complete() {
    let cfg = EngineConfig {
        batcher: BatcherConfig { max_prefills_per_step: 2, ..Default::default() },
        ..default_engine(Precision::Int8)
    };
    let (h, join) = engine::spawn(cfg, cpu_factory());
    let mut router = Router::new(RoutePolicy::RoundRobin);
    router.add_engine("int8", h.clone());

    let mut streams = Vec::new();
    for i in 0..6 {
        let prompt = vec![i as i32 + 1, 7, 9];
        let (_, rx) = router.submit(prompt, 4, SamplingParams::default()).unwrap();
        streams.push(rx);
    }
    for rx in &streams {
        let (tokens, reason, ..) = collect_response(rx);
        assert_eq!(reason, FinishReason::Length);
        assert_eq!(tokens.len(), 4);
    }
    h.drain();
    join.join().unwrap();
    let m = h.metrics.snapshot();
    assert_eq!(m.requests_finished, 6);
    assert_eq!(m.tokens_generated, 24);
    // Continuous batching actually interleaved: fewer steps than a purely
    // sequential run would need (6 prefills + 6*3 decodes = 24 max).
    assert!(m.engine_steps <= 24, "steps {}", m.engine_steps);
}

#[test]
fn fp32_and_int8_engines_agree_on_greedy_tokens() {
    let (h8, j8) = engine::spawn(default_engine(Precision::Int8), cpu_factory());
    let (h32, j32) = engine::spawn(default_engine(Precision::Fp32), cpu_factory());
    let mut router = Router::new(RoutePolicy::RoundRobin);
    router.add_engine("int8", h8.clone());
    router.add_engine("fp32", h32.clone());

    let prompt = vec![5, 6, 7];
    let (_, rx8) = router.submit_to("int8", prompt.clone(), 6, SamplingParams::default()).unwrap();
    let (_, rx32) = router.submit_to("fp32", prompt, 6, SamplingParams::default()).unwrap();
    let (t8, ..) = collect_response(&rx8);
    let (t32, ..) = collect_response(&rx32);
    // INT8 cache error is small enough that greedy trajectories match on
    // this model (the paper's "minimal impact on model behavior" claim).
    assert_eq!(t8, t32);

    h8.drain();
    h32.drain();
    j8.join().unwrap();
    j32.join().unwrap();
}

#[test]
fn int4_engine_serves_requests_end_to_end() {
    // The INT4 serving path (paper §8.1, 8x compression) runs through the
    // zero-copy paged decode — no dense staging layout exists for packed
    // nibbles. Requests must complete normally.
    let (h, join) = engine::spawn(default_engine(Precision::Int4), cpu_factory());
    let mut router = Router::new(RoutePolicy::RoundRobin);
    router.add_engine("int4", h.clone());
    let mut streams = Vec::new();
    for i in 0..3 {
        let (_, rx) = router.submit(vec![i + 1, 8, 4], 4, SamplingParams::default()).unwrap();
        streams.push(rx);
    }
    for rx in &streams {
        let (tokens, reason, ..) = collect_response(rx);
        assert_eq!(reason, FinishReason::Length, "int4 decode failed");
        assert_eq!(tokens.len(), 4);
    }
    h.drain();
    join.join().unwrap();
    assert_eq!(h.metrics.snapshot().requests_finished, 3);

    // And it must be deterministic: same prompt, same greedy tokens.
    let (h2, j2) = engine::spawn(default_engine(Precision::Int4), cpu_factory());
    let mut r2 = Router::new(RoutePolicy::RoundRobin);
    r2.add_engine("int4", h2.clone());
    let (_, rxa) = r2.submit(vec![1, 8, 4], 4, SamplingParams::default()).unwrap();
    let (ta, ..) = collect_response(&rxa);
    let (_, rxb) = r2.submit(vec![1, 8, 4], 4, SamplingParams::default()).unwrap();
    let (tb, ..) = collect_response(&rxb);
    assert_eq!(ta, tb);
    h2.drain();
    j2.join().unwrap();
}

#[test]
fn int4_without_paged_decode_is_rejected_at_startup() {
    // INT4 has no dense staging layout: an engine configured for int4
    // with paged decode disabled must fail fast at init (every request
    // rejected), not burn prefills and die at the first decode step.
    let cfg = EngineConfig { paged_decode: false, ..default_engine(Precision::Int4) };
    let (h, join) = engine::spawn(cfg, cpu_factory());
    let mut router = Router::new(RoutePolicy::RoundRobin);
    router.add_engine("int4", h.clone());
    let (_, rx) = router.submit(vec![1, 2], 2, SamplingParams::default()).unwrap();
    let (tokens, reason, ..) = collect_response(&rx);
    assert!(tokens.is_empty());
    assert!(matches!(reason, FinishReason::Rejected(_)), "{reason:?}");
    h.drain();
    join.join().unwrap();
}

#[test]
fn int4_decode_error_tracks_fp32_within_paper_bound() {
    // Paper-style §8.1 error bound, self-calibrated: the 4-bit grid is
    // (1/14)/(1/254) ≈ 18x coarser than INT8, so INT4 decode logits may
    // drift from the FP32 oracle by at most ~that factor of the measured
    // INT8 drift (generous margin for softmax/layer amplification).
    // The mixed policies must land inside the same frontier: k8v4 keeps
    // keys at INT8 so its drift sits at or below the uniform-int4 bound,
    // and sink8's fp32 sink layer keeps it at or below uniform int8's
    // error scale.
    use kvq::kvcache::manager::{CacheConfig, KvCacheManager};
    use kvq::model::CpuModel;
    use kvq::model::ModelSpec as Spec;
    use kvq::quant::Variant;

    let spec = Spec::test_tiny();
    let model = CpuModel::new(spec.clone(), kvq::model::weights::Weights::synthetic(&spec, 7));
    let tokens: Vec<i32> = vec![3, 1, 4, 1, 5, 9, 2, 6, 5, 3];
    let n = 8;
    let pre = model.prefill(&tokens, n);
    let isa = kvq::quant::simd::default_isa();
    let (l32, ..) = model.decode_f32(tokens[n], n, &pre.k, &pre.v, isa);

    let decode_at = |policy: PolicySpec| -> Vec<f32> {
        let cfg = CacheConfig {
            layers: spec.layers,
            heads: spec.heads,
            head_dim: spec.head_dim,
            max_seq: spec.max_seq,
            block_size: spec.block_size,
            num_blocks: 256,
            scale_margin: 1.0,
        };
        let resolved = policy.resolve(cfg.layers, cfg.heads, cfg.head_dim).unwrap();
        let mut mgr = KvCacheManager::new(cfg, resolved);
        let id = mgr.new_sequence();
        mgr.set_prefill(id, &pre.k, &pre.v, n).unwrap();
        let view = mgr.view(id).unwrap();
        let (logits, ..) =
            model.decode_paged(tokens[n], n, &view, Variant::Vectorized, isa).unwrap();
        logits
    };
    let max_diff = |a: &[f32], b: &[f32]| {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max)
    };
    let diff8 = max_diff(&decode_at(PolicySpec::Uniform(Precision::Int8)), &l32);
    let diff4 = max_diff(&decode_at(PolicySpec::Uniform(Precision::Int4)), &l32);
    assert!(diff4 > 0.0, "int4 quantization noise must register");
    assert!(
        diff4 <= 40.0 * diff8.max(1e-4) + 0.1,
        "int4 drift {diff4} exceeds the paper-style bound (int8 drift {diff8})"
    );
    // k8v4: value-output error from the INT4 V side, attention scores
    // still INT8-grade — bounded by the uniform int4 frontier.
    let diffk = max_diff(&decode_at(PolicySpec::K8V4), &l32);
    assert!(diffk > 0.0, "k8v4 quantization noise must register");
    assert!(
        diffk <= 40.0 * diff8.max(1e-4) + 0.1,
        "k8v4 drift {diffk} exceeds the paper-style fp32-relative bound"
    );
    assert!(
        diffk <= diff4 * 1.5 + 1e-3,
        "k8v4 ({diffk}) should not be materially worse than uniform int4 ({diff4})"
    );
    // sink8 on a 2-layer model keeps layer 0 exact: drift comes from
    // layer 1's INT8 cache only.
    let diffs = max_diff(&decode_at(PolicySpec::Sink8 { sink_layers: 1 }), &l32);
    assert!(
        diffs <= diff8 * 1.5 + 1e-3,
        "sink8 ({diffs}) should track the int8 error scale ({diff8})"
    );
}

#[test]
fn k8v4_policy_serves_end_to_end() {
    // The headline mixed policy (keys INT8 / values INT4) must serve
    // through the paged path: requests complete, generation is
    // deterministic, and the per-precision cache byte split shows both
    // codecs live in one cache.
    let (h, join) = engine::spawn(policy_engine(PolicySpec::K8V4), cpu_factory());
    let mut router = Router::new(RoutePolicy::RoundRobin);
    router.add_engine("k8v4", h.clone());
    let mut streams = Vec::new();
    for i in 0..3 {
        let (_, rx) = router.submit(vec![i + 2, 6, 1], 4, SamplingParams::default()).unwrap();
        streams.push(rx);
    }
    for rx in &streams {
        let (tokens, reason, ..) = collect_response(rx);
        assert_eq!(reason, FinishReason::Length, "k8v4 decode failed");
        assert_eq!(tokens.len(), 4);
    }
    h.drain();
    join.join().unwrap();
    let snap = h.metrics.snapshot();
    assert_eq!(snap.requests_finished, 3);
    assert_eq!(snap.policy, "k8v4");

    // Determinism: same prompt, same greedy tokens, twice.
    let (h2, j2) = engine::spawn(policy_engine(PolicySpec::K8V4), cpu_factory());
    let mut r2 = Router::new(RoutePolicy::RoundRobin);
    r2.add_engine("k8v4", h2.clone());
    let (_, rxa) = r2.submit(vec![2, 6, 1], 4, SamplingParams::default()).unwrap();
    let (ta, ..) = collect_response(&rxa);
    let (_, rxb) = r2.submit(vec![2, 6, 1], 4, SamplingParams::default()).unwrap();
    let (tb, ..) = collect_response(&rxb);
    assert_eq!(ta, tb);
    h2.drain();
    j2.join().unwrap();
}

#[test]
fn non_staging_policies_require_paged_decode() {
    // The generalized fail-fast: any policy without a dense staging ABI
    // (k8v4 here) is rejected at engine init when paged decode is off —
    // same contract the INT4-only special case used to enforce.
    let cfg = EngineConfig { paged_decode: false, ..policy_engine(PolicySpec::K8V4) };
    let (h, join) = engine::spawn(cfg, cpu_factory());
    let mut router = Router::new(RoutePolicy::RoundRobin);
    router.add_engine("k8v4", h.clone());
    let (_, rx) = router.submit(vec![1, 2], 2, SamplingParams::default()).unwrap();
    let (tokens, reason, ..) = collect_response(&rx);
    assert!(tokens.is_empty());
    assert!(matches!(reason, FinishReason::Rejected(_)), "{reason:?}");
    h.drain();
    join.join().unwrap();
}

#[test]
fn oversized_request_is_rejected_cleanly() {
    let (h, join) = engine::spawn(default_engine(Precision::Int8), cpu_factory());
    let mut router = Router::new(RoutePolicy::RoundRobin);
    router.add_engine("int8", h.clone());

    // test_tiny max_seq = 32; this wants 40.
    let (_, rx) = router.submit(vec![1; 20], 20, SamplingParams::default()).unwrap();
    let (tokens, reason, ..) = collect_response(&rx);
    assert!(tokens.is_empty());
    assert!(matches!(reason, FinishReason::Rejected(_)), "{reason:?}");

    h.drain();
    join.join().unwrap();
    assert_eq!(h.metrics.snapshot().requests_rejected, 1);
}

#[test]
fn stop_token_halts_generation() {
    let (h, join) = engine::spawn(default_engine(Precision::Int8), cpu_factory());

    // Use the engine handle directly to set a custom stop token: stop on
    // whatever greedy emits first, so generation ends after 1 token.
    let mut router = Router::new(RoutePolicy::RoundRobin);
    router.add_engine("int8", h.clone());
    let (_, rx0) = router.submit(vec![9, 8, 7], 3, SamplingParams::default()).unwrap();
    let (tokens0, ..) = collect_response(&rx0);
    let first = tokens0[0];

    let mut req = kvq::coordinator::Request::new(router.alloc_id(), vec![9, 8, 7], 10);
    req.stop_token = Some(first);
    let (tx, rx) = std::sync::mpsc::channel();
    h.submit(req, tx).unwrap();
    let (tokens, reason, ..) = collect_response(&rx);
    assert_eq!(reason, FinishReason::Stop);
    assert_eq!(tokens, vec![first]);

    h.drain();
    join.join().unwrap();
}

#[test]
fn capacity_exhaustion_finishes_at_max_seq() {
    let (h, join) = engine::spawn(default_engine(Precision::Int8), cpu_factory());
    let mut router = Router::new(RoutePolicy::RoundRobin);
    router.add_engine("int8", h.clone());
    // prompt 28 + max_new 4 = exactly max_seq: allowed; generation must
    // stop at the boundary (4 tokens == max_new).
    let (_, rx) = router.submit(vec![3; 28], 4, SamplingParams::default()).unwrap();
    let (tokens, reason, ..) = collect_response(&rx);
    assert_eq!(tokens.len(), 4);
    assert!(
        matches!(reason, FinishReason::Length | FinishReason::CapacityExhausted),
        "{reason:?}"
    );
    h.drain();
    join.join().unwrap();
}

#[test]
fn temperature_sampling_varies_with_seed() {
    let (h, join) = engine::spawn(default_engine(Precision::Int8), cpu_factory());
    let mut router = Router::new(RoutePolicy::RoundRobin);
    router.add_engine("int8", h.clone());

    let sp = |seed| SamplingParams { temperature: 2.0, top_k: 0, seed };
    let mut outs = std::collections::HashSet::new();
    for seed in 0..4 {
        let (_, rx) = router.submit(vec![1, 2], 8, sp(seed)).unwrap();
        let (tokens, ..) = collect_response(&rx);
        outs.insert(tokens);
    }
    assert!(outs.len() > 1, "temperature sampling should vary across seeds");
    h.drain();
    join.join().unwrap();
}

#[test]
fn least_loaded_routing_balances() {
    let (h1, j1) = engine::spawn(default_engine(Precision::Int8), cpu_factory());
    let (h2, j2) = engine::spawn(default_engine(Precision::Int8), cpu_factory());
    let mut router = Router::new(RoutePolicy::LeastLoaded);
    router.add_engine("a", h1.clone());
    router.add_engine("b", h2.clone());

    let mut streams = Vec::new();
    for _ in 0..8 {
        let (_, rx) = router.submit(vec![1, 2, 3], 3, SamplingParams::default()).unwrap();
        streams.push(rx);
    }
    for rx in &streams {
        let (_, reason, ..) = collect_response(rx);
        assert_eq!(reason, FinishReason::Length);
    }
    let (m1, m2) = (h1.metrics.snapshot(), h2.metrics.snapshot());
    assert_eq!(m1.requests_finished + m2.requests_finished, 8);
    assert!(m1.requests_finished > 0 && m2.requests_finished > 0, "both engines used");
    h1.drain();
    h2.drain();
    j1.join().unwrap();
    j2.join().unwrap();
}
