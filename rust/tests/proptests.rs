//! Cross-module property tests (util::prop mini-framework).
//!
//! Each property runs hundreds of randomized cases with growing size;
//! failures shrink and report a reproduction seed (KVQ_PROP_SEED).

use kvq::kvcache::manager::{CacheConfig, KvCacheManager};
use kvq::kvcache::{Precision, QuantPolicy};
use kvq::quant::{self, Fp32Matrix, Int8Matrix, Variant};
use kvq::util::json::Json;
use kvq::util::prop::{check, ensure, ensure_close};

fn matrix_from(g: &mut kvq::util::prop::Gen) -> Fp32Matrix {
    let (t, d, data) = g.matrix(1..96, 1..96, 2.0);
    Fp32Matrix::from_vec(t, d, data)
}

#[test]
fn prop_roundtrip_error_bounded() {
    // eq. (9): |x - x̂| <= s_d/2 everywhere, for every distribution.
    check("roundtrip bound", 300, |g| {
        let k = matrix_from(g);
        let q = quant::quantize_fused(&k);
        let r = quant::dequantize(&q);
        for t in 0..k.rows {
            for d in 0..k.cols {
                let err = (k.at(t, d) - r.at(t, d)).abs();
                let bound = q.scales[d] / 2.0 + 1e-6 + q.scales[d].abs() * 1e-5;
                ensure(err <= bound, format!("err {err} > bound {bound} at ({t},{d})"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_all_variants_identical() {
    // Paper §7.5 cross-kernel consistency, for arbitrary shapes/data —
    // including the parallel quantize/dequantize paths across the thread
    // sweep {1, 2, 8}.
    check("variant consistency", 200, |g| {
        let k = matrix_from(g);
        let scales = quant::compute_scales(&k);
        let mut base = Int8Matrix::zeros(k.rows, k.cols);
        quant::quantize::quantize_naive(&k, &scales, &mut base);
        for v in [Variant::Tiled, Variant::Coarsened, Variant::Vectorized] {
            let mut out = Int8Matrix::zeros(k.rows, k.cols);
            quant::quantize::quantize_variant(v, &k, &scales, &mut out);
            ensure(out.data == base.data, format!("{v:?} diverged"))?;
        }
        let rec = quant::dequantize(&base);
        for threads in [1usize, 2, 8] {
            let mut par = Int8Matrix::zeros(k.rows, k.cols);
            quant::quantize_parallel(&k, &scales, &mut par, threads);
            ensure(par.data == base.data, format!("parallel quantize x{threads} diverged"))?;
            let mut prec = Fp32Matrix::zeros(k.rows, k.cols);
            quant::dequantize_parallel(&base, &mut prec, threads);
            ensure(
                prec.data
                    .iter()
                    .zip(&rec.data)
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
                format!("parallel dequantize x{threads} diverged"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_fused_attention_variants_bit_identical() {
    // The zero-copy decode contract (paper §7.5 extended to attention):
    // every dot_i8 / accumulate_rows_i8 variant produces the same bits,
    // for arbitrary slabs, and matches the f64 dequantize-then-dot
    // reference within a stated f32 accumulation tolerance.
    check("fused attention consistency", 200, |g| {
        let k = matrix_from(g);
        let (rows, d) = (k.rows, k.cols);
        let q8 = quant::quantize_fused(&k);
        let mut qrow = vec![0.0f32; d];
        let mut w = vec![0.0f32; rows];
        for v in qrow.iter_mut() {
            *v = g.f32_in(-1.0..1.0);
        }
        for v in w.iter_mut() {
            *v = g.f32_in(0.0..1.0);
        }

        // Score pass.
        let mut base = vec![0.0f32; rows];
        quant::attn::dot_rows_i8(Variant::Naive, &qrow, &q8.data, &q8.scales, &mut base);
        for v in [Variant::Tiled, Variant::Coarsened, Variant::Vectorized] {
            let mut out = vec![0.0f32; rows];
            quant::attn::dot_rows_i8(v, &qrow, &q8.data, &q8.scales, &mut out);
            ensure(
                out.iter().zip(&base).all(|(a, b)| a.to_bits() == b.to_bits()),
                format!("dot {v:?} diverged"),
            )?;
        }
        // f64 dequantize-then-dot reference with a serial-f32-sum bound:
        // |err| <= n·eps·Σ|terms| (+ a tiny absolute floor).
        for r in 0..rows {
            let mut reference = 0.0f64;
            let mut magnitude = 0.0f64;
            for ch in 0..d {
                let term = qrow[ch] as f64 * (q8.data[r * d + ch] as f64 * q8.scales[ch] as f64);
                reference += term;
                magnitude += term.abs();
            }
            let tol = 1e-5 * (d as f64) * magnitude + 1e-6;
            ensure(
                (base[r] as f64 - reference).abs() <= tol,
                format!("row {r}: fused {} vs dequant-then-dot {reference}", base[r]),
            )?;
        }

        // Softmax·V accumulation pass.
        let mut acc_base = vec![0.0f32; d];
        quant::attn::accumulate_rows_i8(Variant::Naive, &w, &q8.data, &q8.scales, &mut acc_base);
        for v in [Variant::Tiled, Variant::Coarsened, Variant::Vectorized] {
            let mut acc = vec![0.0f32; d];
            quant::attn::accumulate_rows_i8(v, &w, &q8.data, &q8.scales, &mut acc);
            ensure(
                acc.iter().zip(&acc_base).all(|(a, b)| a.to_bits() == b.to_bits()),
                format!("accumulate {v:?} diverged"),
            )?;
        }
        for ch in 0..d {
            let mut reference = 0.0f64;
            let mut magnitude = 0.0f64;
            for r in 0..rows {
                let term = w[r] as f64 * (q8.data[r * d + ch] as f64 * q8.scales[ch] as f64);
                reference += term;
                magnitude += term.abs();
            }
            let tol = 1e-5 * (rows as f64) * magnitude + 1e-6;
            ensure(
                (acc_base[ch] as f64 - reference).abs() <= tol,
                format!("ch {ch}: fused {} vs dequant ref {reference}", acc_base[ch]),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_scales_properties() {
    check("scales", 200, |g| {
        let k = matrix_from(g);
        let s = quant::compute_scales(&k);
        // Non-negative; 127*s == column abs max.
        for d in 0..k.cols {
            ensure(s[d] >= 0.0, "negative scale")?;
            let col_max = (0..k.rows).map(|t| k.at(t, d).abs()).fold(0.0f32, f32::max);
            ensure_close(
                s[d] as f64 * 127.0,
                col_max as f64,
                1e-4 * col_max.max(1.0) as f64,
                "s*127 == colmax",
            )?;
        }
        // Parallel agrees exactly.
        let mut sp = vec![0.0; k.cols];
        quant::scales::compute_scales_parallel(&k, &mut sp, 3);
        ensure(sp == s, "parallel scales diverged")?;
        Ok(())
    });
}

#[test]
fn prop_quantized_values_in_range() {
    check("int8 range", 200, |g| {
        let k = matrix_from(g);
        let q = quant::quantize_fused(&k);
        ensure(
            q.data.iter().all(|&v| (-127..=127).contains(&(v as i32))),
            "value outside [-127, 127]",
        )?;
        Ok(())
    });
}

#[test]
fn prop_int4_roundtrip_bound() {
    check("int4 bound", 150, |g| {
        let k = matrix_from(g);
        let q = quant::int4::quantize4(&k);
        let r = quant::int4::dequantize4(&q);
        for t in 0..k.rows {
            for d in 0..k.cols {
                let err = (k.at(t, d) - r.at(t, d)).abs();
                let bound = q.scales[d] / 2.0 + 1e-6 + q.scales[d].abs() * 1e-5;
                ensure(err <= bound, format!("int4 err {err} > {bound}"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_error_metric_identities() {
    check("metric identities", 100, |g| {
        let k = matrix_from(g);
        ensure(quant::l2_error(&k, &k) == 0.0, "l2 self")?;
        ensure(quant::max_abs_error(&k, &k) == 0.0, "maxabs self")?;
        // Symmetry of l2/max-abs.
        let k2 = Fp32Matrix::random_normal(k.rows, k.cols, 1.0, g.rng.next_u64());
        ensure_close(quant::l2_error(&k, &k2), quant::l2_error(&k2, &k), 1e-9, "l2 sym")?;
        ensure_close(
            quant::max_abs_error(&k, &k2),
            quant::max_abs_error(&k2, &k),
            1e-12,
            "maxabs sym",
        )?;
        Ok(())
    });
}

#[test]
fn prop_kvcache_block_conservation() {
    // Random op sequences (new/prefill/append/fork/free) never leak or
    // double-free blocks; freeing everything restores the full pool.
    check("kvcache conservation", 60, |g| {
        let cfg = CacheConfig {
            layers: 1 + g.usize_in(1..3),
            heads: 1 + g.usize_in(1..3),
            head_dim: 4 * (1 + g.usize_in(1..4)),
            max_seq: 32,
            block_size: [4, 8, 16][g.usize_in(0..3)],
            num_blocks: 512,
            scale_margin: 1.0,
        };
        let precision = if g.bool() { Precision::Int8 } else { Precision::Fp32 };
        let mut mgr =
            KvCacheManager::new(cfg, QuantPolicy::uniform(precision, cfg.layers, cfg.heads));
        let n = cfg.layers * cfg.heads * cfg.max_seq * cfg.head_dim;
        let kc: Vec<f32> = (0..n).map(|i| ((i % 13) as f32 - 6.0) / 6.0).collect();
        let row = vec![0.5f32; cfg.layers * cfg.heads * cfg.head_dim];
        let mut live: Vec<u64> = Vec::new();

        for _ in 0..g.usize_in(5..40) {
            match g.usize_in(0..4) {
                0 => {
                    let len = 1 + g.usize_in(0..16);
                    if mgr.can_admit(len) {
                        let id = mgr.new_sequence();
                        mgr.set_prefill(id, &kc, &kc, len).map_err(|e| e.to_string())?;
                        live.push(id);
                    }
                }
                1 => {
                    if let Some(&id) = live.last() {
                        if mgr.seq_len(id).unwrap() < cfg.max_seq
                            && mgr.free_blocks() > 2 * cfg.layers
                        {
                            mgr.append_row(id, &row, &row).map_err(|e| e.to_string())?;
                        }
                    }
                }
                2 => {
                    if !live.is_empty() && mgr.free_blocks() > 0 {
                        let idx = g.usize_in(0..live.len().max(1)) % live.len();
                        let id = mgr.fork(live[idx]).map_err(|e| e.to_string())?;
                        live.push(id);
                    }
                }
                _ => {
                    if !live.is_empty() {
                        let idx = g.usize_in(0..live.len().max(1)) % live.len();
                        mgr.free(live.swap_remove(idx));
                    }
                }
            }
            ensure(mgr.free_blocks() <= cfg.num_blocks, "free > pool")?;
        }
        for id in live {
            mgr.free(id);
        }
        ensure(
            mgr.free_blocks() == cfg.num_blocks,
            format!("leak: {}/{} free after freeing all", mgr.free_blocks(), cfg.num_blocks),
        )?;
        Ok(())
    });
}

#[test]
fn prop_fork_prefix_immutability() {
    // Writes to a fork never alter the parent's visible cache content.
    check("fork isolation", 40, |g| {
        let cfg = CacheConfig {
            layers: 2,
            heads: 2,
            head_dim: 8,
            max_seq: 32,
            block_size: 4,
            num_blocks: 256,
            scale_margin: 1.0,
        };
        let mut mgr =
            KvCacheManager::new(cfg, QuantPolicy::uniform(Precision::Int8, cfg.layers, cfg.heads));
        let n = cfg.layers * cfg.heads * cfg.max_seq * cfg.head_dim;
        let kc: Vec<f32> = (0..n).map(|i| (((i * 31) % 17) as f32 - 8.0) / 8.0).collect();
        let len = 1 + g.usize_in(0..20);
        let parent = mgr.new_sequence();
        mgr.set_prefill(parent, &kc, &kc, len).map_err(|e| e.to_string())?;

        let hsd = cfg.heads * cfg.max_seq * cfg.head_dim;
        let mut before = vec![0i8; hsd];
        mgr.gather_i8(parent, 0, 0, &mut before).map_err(|e| e.to_string())?;

        let fork = mgr.fork(parent).map_err(|e| e.to_string())?;
        let row = vec![9.0f32; cfg.layers * cfg.heads * cfg.head_dim];
        for _ in 0..g.usize_in(1..8) {
            if mgr.seq_len(fork).unwrap() >= cfg.max_seq {
                break;
            }
            mgr.append_row(fork, &row, &row).map_err(|e| e.to_string())?;
        }
        let mut after = vec![0i8; hsd];
        mgr.gather_i8(parent, 0, 0, &mut after).map_err(|e| e.to_string())?;
        ensure(before == after, "parent cache mutated by fork writes")?;
        mgr.free(parent);
        mgr.free(fork);
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip() {
    // Random JSON trees survive write→parse exactly.
    check("json roundtrip", 300, |g| {
        fn gen_json(g: &mut kvq::util::prop::Gen, depth: usize) -> Json {
            match if depth == 0 { g.usize_in(0..4) } else { g.usize_in(0..6) } {
                0 => Json::Null,
                1 => Json::Bool(g.bool()),
                2 => Json::Num((g.i64_in(-1_000_000..1_000_000)) as f64),
                3 => {
                    let n = g.usize_in(0..12);
                    Json::Str(
                        (0..n)
                            .map(|_| {
                                *g.choice(&['a', 'Z', '0', ' ', '"', '\\', '\n', '≈', '😀'])
                            })
                            .collect(),
                    )
                }
                4 => Json::Arr((0..g.usize_in(0..5)).map(|_| gen_json(g, depth - 1)).collect()),
                _ => Json::Obj(
                    (0..g.usize_in(0..5))
                        .map(|i| (format!("k{i}"), gen_json(g, depth - 1)))
                        .collect(),
                ),
            }
        }
        let v = gen_json(g, 3);
        let text = v.to_string();
        let back = Json::parse(&text).map_err(|e| format!("{e} on {text:?}"))?;
        ensure(back == v, format!("roundtrip mismatch: {text}"))?;
        Ok(())
    });
}

#[test]
fn prop_histogram_quantiles_bracket_samples() {
    check("histogram quantiles", 100, |g| {
        let mut h = kvq::util::stats::LogHistogram::latency();
        let n = 50 + g.usize_in(0..500);
        let lo = g.f32_in(1e-5..1e-2) as f64;
        let hi = lo * (1.0 + g.f32_in(0.1..10.0) as f64);
        for _ in 0..n {
            h.record(lo + (hi - lo) * g.rng.next_f64());
        }
        let p50 = h.quantile(0.5);
        // Log-bucket error is bounded by one bucket ratio (1.3x).
        ensure(p50 >= lo / 1.3 && p50 <= hi * 1.3, format!("p50 {p50} outside [{lo},{hi}]"))?;
        ensure(h.quantile(1.0) <= hi * 1.3 + 1e-12, "p100 above max")?;
        Ok(())
    });
}

#[test]
fn prop_per_channel_bound_dominates_per_tensor_bound() {
    // The *bounds* ordering that motivates eq. (6): every per-channel
    // scale is <= the global scale, so the per-channel worst case s_d/2
    // is column-wise tighter. (Realized errors can flip by rounding luck
    // on individual elements, so we assert the bound, not the sample.)
    check("per-channel bound dominance", 100, |g| {
        let k = matrix_from(g);
        let pc = quant::quantize_fused(&k);
        let pt = quant::tensorwise::quantize_tensorwise(&k);
        let s_global = pt.scales[0];
        for (d, &s) in pc.scales.iter().enumerate() {
            ensure(
                s <= s_global * (1.0 + 1e-6) + 1e-12,
                format!("channel {d}: per-channel scale {s} > global {s_global}"),
            )?;
        }
        // And the realized per-channel error respects the global bound.
        let rec = quant::dequantize(&pc);
        let e_pc = quant::max_abs_error(&k, &rec);
        ensure(
            e_pc <= (s_global / 2.0 + 1e-6 + s_global.abs() * 1e-5) as f64,
            format!("pc err {e_pc} above global bound {}", s_global / 2.0),
        )?;
        Ok(())
    });
}

#[test]
fn prop_simd_backend_matches_scalar_within_tolerance() {
    // The kernel_backend contract, property-tested on whatever ISA this
    // host detects (falls back to a scalar-vs-scalar dispatch check on
    // hosts without SIMD): encode and decode emit bit-identical bytes,
    // softmax-V accumulation is bit-identical, and the score-pass dot —
    // the one kernel allowed to reassociate — stays within 1e-5-grade
    // relative error of the f64 dequantize-then-dot reference.
    use kvq::quant::simd::{self, Isa};
    let isa = simd::KernelBackend::Simd.resolve_with(None);
    check("simd vs scalar", 120, |g| {
        let k = matrix_from(g);
        let (rows, d) = (k.rows, k.cols);
        let q8 = quant::quantize_fused(&k);
        let mut qrow = vec![0.0f32; d];
        let mut w = vec![0.0f32; rows];
        for v in qrow.iter_mut() {
            *v = g.f32_in(-1.0..1.0);
        }
        for v in w.iter_mut() {
            *v = g.f32_in(0.0..1.0);
        }
        let bits = |x: &[f32]| x.iter().map(|v| v.to_bits()).collect::<Vec<_>>();

        // Encode: byte-identical on every backend.
        let scales = quant::compute_scales(&k);
        for t in 0..rows {
            let mut scalar = vec![0i8; d];
            let mut simd_out = vec![0i8; d];
            quant::quantize_row_into(k.row(t), &scales, &mut scalar);
            simd::quantize_row_into(isa, k.row(t), &scales, &mut simd_out);
            ensure(scalar == simd_out, format!("encode diverged at row {t} ({rows}x{d})"))?;
        }

        // Decode: bit-identical.
        let mut scalar_dec = vec![0.0f32; d];
        let mut simd_dec = vec![0.0f32; d];
        quant::dequantize::dequantize_row_into(&q8.data[..d], &q8.scales, &mut scalar_dec);
        simd::dequantize_row_into(isa, &q8.data[..d], &q8.scales, &mut simd_dec);
        ensure(bits(&scalar_dec) == bits(&simd_dec), "decode diverged")?;

        // Accumulate: bit-identical (same per-channel op order).
        let mut scalar_acc = vec![0.0f32; d];
        let mut simd_acc = vec![0.0f32; d];
        quant::attn::accumulate_rows_i8(Variant::Naive, &w, &q8.data, &q8.scales, &mut scalar_acc);
        simd::accumulate_rows_i8(isa, Variant::Naive, &w, &q8.data, &q8.scales, &mut simd_acc);
        ensure(bits(&scalar_acc) == bits(&simd_acc), "accumulate diverged")?;

        // Dot: f64 reference within the serial-f32-sum style bound.
        let mut got = vec![0.0f32; rows];
        simd::dot_rows_i8(isa, Variant::Vectorized, &qrow, &q8.data, &q8.scales, &mut got);
        for r in 0..rows {
            let mut reference = 0.0f64;
            let mut magnitude = 0.0f64;
            for ch in 0..d {
                let term =
                    qrow[ch] as f64 * (q8.data[r * d + ch] as f64 * q8.scales[ch] as f64);
                reference += term;
                magnitude += term.abs();
            }
            let tol = 1e-5 * (d as f64) * magnitude + 1e-6;
            ensure(
                (got[r] as f64 - reference).abs() <= tol,
                format!("row {r}: simd dot {} vs f64 ref {reference}", got[r]),
            )?;
        }

        // INT4 (even d only): encode/decode bit-identical, fused dot in
        // tolerance vs the scalar arm.
        if d % 2 == 0 {
            let q4 = quant::int4::quantize4(&k);
            let bpr = d / 2;
            let mut scalar_pack = vec![0u8; bpr];
            let mut simd_pack = vec![0u8; bpr];
            quant::int4::quantize4_row_into(k.row(0), &q4.scales, &mut scalar_pack);
            simd::quantize4_row_into(isa, k.row(0), &q4.scales, &mut simd_pack);
            ensure(scalar_pack == simd_pack, "int4 encode diverged")?;
            let mut scalar_un = vec![0.0f32; d];
            let mut simd_un = vec![0.0f32; d];
            quant::int4::dequantize4_row_into(&q4.data[..bpr], &q4.scales, &mut scalar_un);
            simd::dequantize4_row_into(isa, &q4.data[..bpr], &q4.scales, &mut simd_un);
            ensure(bits(&scalar_un) == bits(&simd_un), "int4 decode diverged")?;
            let mut scratch = Vec::new();
            let mut scalar_dot = vec![0.0f32; rows];
            let mut simd_dot = vec![0.0f32; rows];
            simd::dot_rows_i4(
                Isa::Scalar,
                &qrow,
                &q4.data,
                &q4.scales,
                &mut scratch,
                &mut scalar_dot,
            );
            simd::dot_rows_i4(isa, &qrow, &q4.data, &q4.scales, &mut scratch, &mut simd_dot);
            for r in 0..rows {
                let tol = 1e-5 * scalar_dot[r].abs().max(1.0) * d as f32;
                ensure(
                    (scalar_dot[r] - simd_dot[r]).abs() <= tol,
                    format!("int4 dot row {r} diverged beyond tolerance"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn simd_backend_pinned_head_dims() {
    // The decode-relevant head_dim set from the issue: 1 and 3 (odd,
    // below any vector width), 64 and 128 (the serving shapes), and 129
    // (odd remainder past the widest chunk). Every codec path must agree
    // with scalar per the contract at each shape.
    use kvq::quant::simd::{self, Isa};
    let isa = simd::detect();
    for d in [1usize, 3, 64, 128, 129] {
        for rows in [1usize, 7] {
            let k = Fp32Matrix::random_normal(rows, d, 1.0, (d * 31 + rows) as u64);
            let q8 = quant::quantize_fused(&k);
            let scales = quant::compute_scales(&k);
            let mut rng = kvq::util::rng::Rng::new(d as u64);
            let mut q = vec![0.0f32; d];
            let mut w = vec![0.0f32; rows];
            rng.fill_uniform(&mut q, -1.0, 1.0);
            rng.fill_uniform(&mut w, 0.0, 1.0);
            let bits = |x: &[f32]| x.iter().map(|v| v.to_bits()).collect::<Vec<_>>();

            for t in 0..rows {
                let mut a = vec![0i8; d];
                let mut b = vec![0i8; d];
                quant::quantize_row_into(k.row(t), &scales, &mut a);
                simd::quantize_row_into(isa, k.row(t), &scales, &mut b);
                assert_eq!(a, b, "encode d={d} rows={rows} t={t}");
            }
            let mut a = vec![0.0f32; d];
            let mut b = vec![0.0f32; d];
            quant::dequantize::dequantize_row_into(&q8.data[..d], &q8.scales, &mut a);
            simd::dequantize_row_into(isa, &q8.data[..d], &q8.scales, &mut b);
            assert_eq!(bits(&a), bits(&b), "decode d={d}");

            let mut acc_a = vec![0.5f32; d];
            let mut acc_b = vec![0.5f32; d];
            quant::attn::accumulate_rows_i8(
                Variant::Vectorized,
                &w,
                &q8.data,
                &q8.scales,
                &mut acc_a,
            );
            simd::accumulate_rows_i8(
                isa,
                Variant::Vectorized,
                &w,
                &q8.data,
                &q8.scales,
                &mut acc_b,
            );
            assert_eq!(bits(&acc_a), bits(&acc_b), "accumulate d={d} rows={rows}");

            let mut dot_b = vec![0.0f32; rows];
            simd::dot_rows_i8(isa, Variant::Vectorized, &q, &q8.data, &q8.scales, &mut dot_b);
            for r in 0..rows {
                let mut reference = 0.0f64;
                let mut magnitude = 0.0f64;
                for ch in 0..d {
                    let term =
                        q[ch] as f64 * (q8.data[r * d + ch] as f64 * q8.scales[ch] as f64);
                    reference += term;
                    magnitude += term.abs();
                }
                let tol = 1e-5 * (d as f64) * magnitude + 1e-6;
                assert!(
                    (dot_b[r] as f64 - reference).abs() <= tol,
                    "dot d={d} rows={rows} r={r}: {} vs {reference}",
                    dot_b[r]
                );
            }

            // INT4 at the even dims (policy forbids odd head_dim).
            if d % 2 == 0 {
                let q4 = quant::int4::quantize4(&k);
                let mut scratch = Vec::new();
                let mut acc4_a = vec![0.25f32; d];
                let mut acc4_b = vec![0.25f32; d];
                simd::accumulate_rows_i4(
                    Isa::Scalar,
                    &w,
                    &q4.data,
                    &q4.scales,
                    &mut scratch,
                    &mut acc4_a,
                );
                simd::accumulate_rows_i4(
                    isa,
                    &w,
                    &q4.data,
                    &q4.scales,
                    &mut scratch,
                    &mut acc4_b,
                );
                assert_eq!(bits(&acc4_a), bits(&acc4_b), "int4 accumulate d={d}");
            }
        }
    }
}
