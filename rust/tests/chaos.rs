//! Chaos suite: deterministic fault injection (`util::fault`) driven
//! end-to-end through the serving stack. The contract under test:
//!
//! 1. **Zero lost streams.** Whatever dies — an engine thread panics, a
//!    deadline expires, a client walks away, a cold-tier entry fails to
//!    decompress — every submitted stream terminates with a typed
//!    `FinishReason`. Nothing hangs (every collect in this file runs
//!    under a hard timeout).
//! 2. **Byte-identical re-drives.** Per-request determinism (engine
//!    seed, prompt, sampling seed — never request ids or timing) means a
//!    failed request replayed after recovery produces exactly the tokens
//!    the uninjected run would have; a shard death costs latency, never
//!    different bytes.
//! 3. **Supervised recovery.** The router's supervisor respawns dead
//!    shards and the fleet serves again, while healthy shards keep
//!    serving throughout.
//! 4. **Balanced accounting.** After cancellation churn the engine's
//!    block-pool refcounts check out (`EngineHandle::check`) and depth
//!    drains to zero.
//!
//! Every test installs its fault plan with `fault::install_global` and
//! holds the returned guard for its whole active phase: the guard owns
//! the global fault lock, so chaos tests serialize against each other
//! (and against fault-using unit tests) even under parallel libtest.
//! Rules are count-limited so post-injection phases run fault-free under
//! the same guard. CI additionally runs this binary with
//! `--test-threads=1`.

use kvq::coordinator::admission::{AdmissionConfig, AdmissionMode};
use kvq::coordinator::batcher::BatcherConfig;
use kvq::coordinator::engine::{self, EngineConfig, ShardState};
use kvq::coordinator::request::{EventRx, FinishReason, TokenEvent};
use kvq::coordinator::router::{Affinity, RoutePolicy, Router, RouterConfig, SubmitOptions};
use kvq::coordinator::EngineHandle;
use kvq::kvcache::{PolicySpec, Precision};
use kvq::model::runner::CpuBackend;
use kvq::model::sample::SamplingParams;
use kvq::model::weights::Weights;
use kvq::model::{LmBackend, ModelSpec};
use kvq::server::http::HttpRequest;
use kvq::server::KvqService;
use kvq::util::fault;
use std::sync::mpsc::RecvTimeoutError;
use std::sync::Arc;
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Harness
// ---------------------------------------------------------------------------

fn cpu_backend() -> anyhow::Result<Box<dyn LmBackend>> {
    let spec = ModelSpec::test_tiny();
    let w = Weights::synthetic(&spec, 7);
    Ok(Box::new(CpuBackend::new(spec, w)) as Box<dyn LmBackend>)
}

fn engine_cfg() -> EngineConfig {
    EngineConfig {
        quant_policy: PolicySpec::uniform(Precision::Int8),
        seed: 42,
        ..Default::default()
    }
}

/// Distinct, vocab-safe 8-token prompts (test-tiny vocab = 64;
/// max_seq = 32, so max_new stays <= 24 everywhere in this file).
fn mk_prompt(i: usize) -> Vec<i32> {
    (0..8).map(|j| ((i as i32 + 3) * 7 + j) % 64).collect()
}

/// Submit through the router with options, panicking on rejection —
/// chaos tests never expect saturation.
fn go(router: &Router, prompt: &[i32], max_new: usize, opts: SubmitOptions) -> EventRx {
    router.submit_with(prompt.to_vec(), max_new, SamplingParams::default(), opts).unwrap().1
}

/// Collect a stream under a hard timeout: a hang is a test failure, not
/// a CI timeout. Dropped-without-Finished is a lost stream — also fatal.
fn collect_timeout(rx: &EventRx, cap: Duration) -> (Vec<i32>, FinishReason) {
    let deadline = Instant::now() + cap;
    let mut tokens = Vec::new();
    loop {
        let left = deadline.saturating_duration_since(Instant::now());
        match rx.recv_timeout(left) {
            Ok(TokenEvent::First { token, .. }) => tokens.push(token),
            Ok(TokenEvent::Token(t)) => tokens.push(t),
            Ok(TokenEvent::Finished { reason, .. }) => return (tokens, reason),
            Err(RecvTimeoutError::Timeout) => {
                panic!("stream hung: no event within {cap:?} (lost-stream bug)")
            }
            Err(RecvTimeoutError::Disconnected) => {
                panic!("stream dropped without a Finished event (lost-stream bug)")
            }
        }
    }
}

/// A router over `n` supervised shards (identical seed-42 engines, so
/// placement never changes tokens), with its supervisor thread running.
fn supervised_fleet(n: usize) -> (Arc<Router>, std::thread::JoinHandle<()>) {
    let mut router = Router::with_config(RouterConfig {
        policy: RoutePolicy::LeastLoaded,
        affinity: Affinity::None,
        ..Default::default()
    });
    for i in 0..n {
        router.add_supervised(
            &format!("shard{i}"),
            Box::new(|metrics, health| {
                engine::spawn_with(engine_cfg(), || cpu_backend(), metrics, health)
            }),
        );
    }
    let router = Arc::new(router);
    let sup = router.spawn_supervisor();
    (router, sup)
}

fn shutdown_fleet(router: Arc<Router>, sup: std::thread::JoinHandle<()>) {
    router.stop_supervisor();
    sup.join().unwrap();
    for (_, h) in router.shards() {
        h.drain();
    }
}

fn single_engine() -> (Router, EngineHandle, std::thread::JoinHandle<()>) {
    let (h, join) = engine::spawn(engine_cfg(), || cpu_backend());
    let mut router = Router::new(RoutePolicy::RoundRobin);
    router.add_engine("e", h.clone());
    (router, h, join)
}

fn default_opts() -> SubmitOptions {
    SubmitOptions::default()
}

// ---------------------------------------------------------------------------
// Tentpole: shard death -> typed failures -> respawn -> identical re-drives
// ---------------------------------------------------------------------------

#[test]
fn shard_panic_fails_streams_typed_then_respawns_and_redrives_identically() {
    // One-shot panic on the 4th decode wave across the fleet: whichever
    // shard reaches it dies mid-trace with live and queued streams.
    let spec = r#"[{"site":"decode_wave","action":"panic","nth":4,"count":1}]"#;
    let _guard = fault::install_global(spec).unwrap();
    let prompts: Vec<Vec<i32>> = (0..9).map(mk_prompt).collect();
    let max_new = 12;

    let (router, sup) = supervised_fleet(3);
    let mut streams = Vec::new();
    for p in &prompts {
        streams.push(go(&router, p, max_new, default_opts()));
    }

    // Zero hangs, zero lost streams: every submission terminates typed.
    let mut failed: Vec<usize> = Vec::new();
    let mut survived: Vec<(usize, Vec<i32>)> = Vec::new();
    for (i, rx) in streams.iter().enumerate() {
        let (tokens, reason) = collect_timeout(rx, Duration::from_secs(30));
        match reason {
            FinishReason::Length => survived.push((i, tokens)),
            FinishReason::ShardFailed => failed.push(i),
            other => panic!("stream {i}: want Length or ShardFailed, got {other:?}"),
        }
    }
    assert!(!failed.is_empty(), "the injected panic must fail at least one stream");
    assert!(!survived.is_empty(), "healthy shards must keep serving through the death");
    let mut streams_failed = 0;
    for (_, h) in router.shards() {
        streams_failed += h.metrics.snapshot().streams_failed as usize;
    }
    assert_eq!(streams_failed, failed.len(), "failure accounting must balance");

    // The supervisor respawns the dead shard and books the restart.
    let t0 = Instant::now();
    loop {
        let states = router.shard_states();
        let all_ok = states.iter().all(|(_, s, _)| *s == ShardState::Ok);
        let restarts: u64 = states.iter().map(|(_, _, r)| r).sum();
        if all_ok && restarts >= 1 {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "supervisor must respawn the dead shard; states: {states:?}"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(router.stats().shard_restarts >= 1);

    // Reference bytes: the one-shot rule is exhausted, so a fresh
    // uninjected engine (same seed) is the canonical run.
    let (ref_router, ref_h, ref_join) = single_engine();
    let mut reference = Vec::new();
    for p in &prompts {
        let rx = go(&ref_router, p, max_new, default_opts());
        let (tokens, reason) = collect_timeout(&rx, Duration::from_secs(30));
        assert_eq!(reason, FinishReason::Length);
        reference.push(tokens);
    }
    ref_h.drain();
    ref_join.join().unwrap();

    for (i, tokens) in &survived {
        assert_eq!(tokens, &reference[*i], "surviving stream {i} must match uninjected run");
    }

    // Re-drive every failed stream through the healed fleet: determinism
    // makes the replay byte-identical — the failure cost latency only.
    for &i in &failed {
        let rx = go(&router, &prompts[i], max_new, default_opts());
        let (tokens, reason) = collect_timeout(&rx, Duration::from_secs(30));
        assert_eq!(reason, FinishReason::Length, "re-drive {i} must finish");
        assert_eq!(tokens, reference[i], "re-drive {i} must be byte-identical");
    }

    // Every shard — including the respawned one — serves again.
    for s in 0..3 {
        let opts = SubmitOptions { shard: Some(s), ..Default::default() };
        let rx = go(&router, &prompts[0], max_new, opts);
        let (tokens, reason) = collect_timeout(&rx, Duration::from_secs(30));
        assert_eq!(reason, FinishReason::Length, "shard {s} must serve after recovery");
        assert_eq!(tokens, reference[0]);
    }
    shutdown_fleet(router, sup);
}

// ---------------------------------------------------------------------------
// Deadlines and client cancellation
// ---------------------------------------------------------------------------

#[test]
fn deadline_expires_as_typed_cancel_and_frees_state() {
    // An 80ms injected prefill delay guarantees the 1ms deadline is long
    // gone by the first post-prefill sweep, whichever path (expired in
    // waiting, or cancelled mid-decode) catches it first.
    let spec = r#"[{"site":"prefill","action":"delay","delay_ms":80,"nth":1,"count":1}]"#;
    let _guard = fault::install_global(spec).unwrap();
    let (router, h, join) = single_engine();
    let opts = SubmitOptions { deadline_ms: Some(1), ..Default::default() };
    let rx = go(&router, &mk_prompt(0), 24, opts);
    let (_, reason) = collect_timeout(&rx, Duration::from_secs(30));
    assert_eq!(reason, FinishReason::DeadlineExceeded);

    // The engine is healthy and balanced afterwards: a clean request
    // (no deadline) runs to completion on the same shard.
    let rx = go(&router, &mk_prompt(1), 8, default_opts());
    let (tokens, reason) = collect_timeout(&rx, Duration::from_secs(30));
    assert_eq!(reason, FinishReason::Length);
    assert_eq!(tokens.len(), 8);
    h.check().expect("refcounts must balance after a deadline cancel");
    h.drain();
    join.join().unwrap();
    assert_eq!(h.metrics.snapshot().deadline_cancels, 1);
}

#[test]
fn client_drop_cancels_stream_and_frees_blocks() {
    // Slowed waves keep the stream alive long enough to observe the
    // cancel; the client receives its first token, then walks away.
    let spec = r#"[{"site":"decode_wave","action":"delay","delay_ms":5,"nth":1,"count":0}]"#;
    let _guard = fault::install_global(spec).unwrap();
    let (router, h, join) = single_engine();
    let rx = go(&router, &mk_prompt(0), 16, default_opts());
    match rx.recv_timeout(Duration::from_secs(30)) {
        Ok(TokenEvent::First { .. }) => {}
        other => panic!("expected a first token, got {other:?}"),
    }
    drop(rx);

    let t0 = Instant::now();
    while h.metrics.snapshot().client_cancels == 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "engine must notice the dropped receiver and cancel"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    h.check().expect("refcounts must balance after a client cancel");
    h.drain();
    join.join().unwrap();
    let m = h.metrics.snapshot();
    assert_eq!(m.client_cancels, 1);
    assert_eq!(m.running, 0);
    assert_eq!(m.preempted, 0);
}

#[test]
fn churned_cancellations_keep_refcounts_balanced() {
    // Alternating deadline expiries and client drops under slowed waves:
    // after the churn the pool must be fully reclaimed, refcounts
    // consistent, and the shard still serving.
    let spec = r#"[{"site":"decode_wave","action":"delay","delay_ms":5,"nth":1,"count":0}]"#;
    let _guard = fault::install_global(spec).unwrap();
    let (router, h, join) = single_engine();
    let mut held = Vec::new();
    for i in 0..8 {
        let opts = SubmitOptions {
            deadline_ms: if i % 2 == 0 { Some(1) } else { None },
            ..Default::default()
        };
        let rx = go(&router, &mk_prompt(i), 16, opts);
        if i % 2 == 0 {
            held.push(rx); // deadline path: collect the typed cancel
        } else {
            drop(rx); // client-drop path: server-side cancel
        }
    }
    for rx in &held {
        let (_, reason) = collect_timeout(rx, Duration::from_secs(30));
        assert_eq!(reason, FinishReason::DeadlineExceeded);
    }
    let t0 = Instant::now();
    while h.metrics.depth() > 0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "depth must drain to zero");
        std::thread::sleep(Duration::from_millis(5));
    }
    h.check().expect("refcounts must balance after cancellation churn");

    let rx = go(&router, &mk_prompt(9), 8, default_opts());
    let (_, reason) = collect_timeout(&rx, Duration::from_secs(30));
    assert_eq!(reason, FinishReason::Length, "shard must still serve after churn");
    h.drain();
    join.join().unwrap();
    let m = h.metrics.snapshot();
    assert_eq!(m.deadline_cancels, 4);
    assert!(m.client_cancels >= 1, "dropped receivers must be booked (got 0)");
}

// ---------------------------------------------------------------------------
// Watchdog
// ---------------------------------------------------------------------------

#[test]
fn watchdog_cancels_stalled_preempted_stream() {
    // A 16-block pool fits exactly one full-length sequence. Two growing
    // sequences collide: the loser is preempted and can never resume
    // while the winner holds the blocks (its replay needs 12, at most 4
    // are free). Slowed waves keep the winner running far past 2x the
    // stall timeout, so the watchdog must cancel the parked stream typed
    // instead of letting it wait forever.
    let spec = r#"[{"site":"decode_wave","action":"delay","delay_ms":25,"nth":1,"count":0}]"#;
    let _guard = fault::install_global(spec).unwrap();
    let cfg = EngineConfig {
        num_blocks: Some(16),
        stall_timeout_ms: 60,
        batcher: BatcherConfig {
            max_prefills_per_step: 2,
            admission: AdmissionConfig {
                mode: AdmissionMode::Optimistic,
                max_running: 8,
                ..Default::default()
            },
            ..Default::default()
        },
        ..engine_cfg()
    };
    let (h, join) = engine::spawn(cfg, || cpu_backend());
    let mut router = Router::new(RoutePolicy::RoundRobin);
    router.add_engine("w", h.clone());
    let rx_a = go(&router, &mk_prompt(0), 20, default_opts());
    let rx_b = go(&router, &mk_prompt(1), 20, default_opts());
    let (ta, ra) = collect_timeout(&rx_a, Duration::from_secs(60));
    let (tb, rb) = collect_timeout(&rx_b, Duration::from_secs(60));

    let reasons = [ra.clone(), rb.clone()];
    assert!(
        reasons.contains(&FinishReason::Stalled),
        "one stream must be watchdog-cancelled (got {ra:?} / {rb:?})"
    );
    assert!(
        reasons.contains(&FinishReason::Length),
        "the winner must finish normally (got {ra:?} / {rb:?})"
    );
    assert_eq!(ta.len().max(tb.len()), 20, "the winner streams every token");
    h.check().expect("refcounts must balance after a stall cancel");
    h.drain();
    join.join().unwrap();
    let m = h.metrics.snapshot();
    assert_eq!(m.stall_cancels, 1);
    assert!(m.preemptions >= 1, "the collision must preempt the loser");
    assert_eq!(m.preempted, 0, "the cancel must remove the parked stream");
}

// ---------------------------------------------------------------------------
// Cold-tier decompression failure through the serving path
// ---------------------------------------------------------------------------

/// CI tier-off / cache-off env jobs force the tier disabled; identity
/// assertions still hold, tier-counter expectations are skipped.
fn tier_forced_off() -> bool {
    matches!(std::env::var("KVQ_COLD_TIER").as_deref(), Ok("off") | Ok("0"))
        || std::env::var("KVQ_PREFIX_CACHE_BLOCKS").as_deref() == Ok("0")
}

#[test]
fn tier_decompress_failure_falls_back_to_prefill_bit_identically() {
    // Every cold-tier decompression fails (injected). Serving four
    // prompts through a 16-block pool demotes the LRU prompt to the
    // tier; resubmitting it promotes -> decompress fails typed -> the
    // entry is dropped and the request re-prefills. The bytes must be
    // exactly the first run's.
    let spec = r#"[{"site":"tier_decompress","action":"error","nth":1,"count":0}]"#;
    let _guard = fault::install_global(spec).unwrap();
    let cfg = EngineConfig {
        num_blocks: Some(16),
        prefix_cache_blocks: 64,
        cold_tier_blocks: Some(64),
        prefetch_depth: 0, // synchronous promotion: the fault path is deterministic
        batcher: BatcherConfig {
            max_prefills_per_step: 1,
            admission: AdmissionConfig {
                mode: AdmissionMode::Optimistic,
                max_running: 8,
                ..Default::default()
            },
            ..Default::default()
        },
        ..engine_cfg()
    };
    let (h, join) = engine::spawn(cfg, || cpu_backend());
    let mut router = Router::new(RoutePolicy::RoundRobin);
    router.add_engine("t", h.clone());

    let prompts: Vec<Vec<i32>> = (0..4).map(mk_prompt).collect();
    let mut first = Vec::new();
    for p in &prompts {
        let rx = go(&router, p, 8, default_opts());
        let (tokens, reason) = collect_timeout(&rx, Duration::from_secs(30));
        assert_eq!(reason, FinishReason::Length);
        first.push(tokens);
    }

    let rx = go(&router, &prompts[0], 8, default_opts());
    let (tokens, reason) = collect_timeout(&rx, Duration::from_secs(30));
    assert_eq!(reason, FinishReason::Length, "decompress failure must not fail the stream");
    assert_eq!(tokens, first[0], "prefill fallback must be byte-identical");
    h.check().expect("refcounts must balance after a dropped cold entry");
    h.drain();
    join.join().unwrap();
    if !tier_forced_off() {
        let m = h.metrics.snapshot();
        assert!(
            m.tier.decompress_errors >= 1,
            "the resubmit must have promoted and failed (demotions={}, errors={})",
            m.tier.demotions,
            m.tier.decompress_errors
        );
    }
}

// ---------------------------------------------------------------------------
// Service layer: typed HTTP mapping of the new terminal reasons
// ---------------------------------------------------------------------------

fn post(svc: &KvqService, path: &str, body: &str) -> (u16, String) {
    let resp = svc.handle(HttpRequest {
        method: "POST".into(),
        path: path.into(),
        headers: Default::default(),
        body: body.as_bytes().to_vec(),
    });
    (resp.status, String::from_utf8(resp.body).unwrap())
}

#[test]
fn service_maps_deadline_expiry_to_408() {
    let spec = r#"[{"site":"prefill","action":"delay","delay_ms":80,"nth":1,"count":1}]"#;
    let _guard = fault::install_global(spec).unwrap();
    let (h, join) = engine::spawn(engine_cfg(), || cpu_backend());
    let mut router = Router::with_config(RouterConfig {
        default_deadline_ms: 1, // every request inherits a 1ms deadline
        ..Default::default()
    });
    router.add_engine("d", h.clone());
    let svc = KvqService::new(Arc::new(router));
    let (status, body) = post(&svc, "/generate", r#"{"prompt":"hello","max_new_tokens":16}"#);
    assert_eq!(status, 408, "expired deadline must map to 408 (body: {body})");
    assert!(body.contains("deadline_exceeded"), "typed code expected, got: {body}");
    h.drain();
    join.join().unwrap();
}

#[test]
fn service_maps_shard_death_to_503_with_retry_hint() {
    let spec = r#"[{"site":"prefill","action":"panic","nth":1,"count":1}]"#;
    let _guard = fault::install_global(spec).unwrap();
    let (h, join) = engine::spawn(engine_cfg(), || cpu_backend());
    let mut router = Router::new(RoutePolicy::RoundRobin);
    router.add_engine("s", h.clone());
    let svc = KvqService::new(Arc::new(router));
    let (status, body) = post(&svc, "/generate", r#"{"prompt":"hello","max_new_tokens":8}"#);
    assert_eq!(status, 503, "a mid-request shard death must map to 503 (body: {body})");
    assert!(body.contains("shard_failed"), "typed code expected, got: {body}");
    assert!(body.contains("retry_after_ms"), "retry hint expected, got: {body}");
    join.join().unwrap(); // the engine thread exited through its panic recovery
    drop(h);
}
