//! Tiered-cache invariants the compressed cold tier must hold:
//!
//! 1. demote → promote is bit-identical — the restored blocks feed every
//!    fused kernel variant (naive/tiled/coarsened/vectorized) × ISA
//!    (scalar, SIMD) and produce exactly the pre-demotion outputs, for
//!    both uniform INT8 and the mixed k8v4 policy (sub-pool widths).
//! 2. a prompt whose blocks are shared with a live sequence is never
//!    demoted out from under the writer: demotion refuses while the span
//!    is shared, and once the writer COW-appends, the captured bytes are
//!    the original rows, not the writer's mutation.
//! 3. the persistent snapshot round-trips across an engine restart:
//!    a second engine on the same `snapshot_path` serves repeat prompts
//!    token-identically, restoring entries from disk and promoting them
//!    instead of re-prefilling blind.
//! 4. a constrained pool with the tier on produces exactly the tokens of
//!    the tier-off and unconstrained runs — demotion only changes *where*
//!    cached bytes live, never *what* gets computed.
//!
//! The CI tier-off job reruns this binary with `KVQ_COLD_TIER=off`
//! (and the cache-off job with `KVQ_PREFIX_CACHE_BLOCKS=0`, which also
//! disables the tier): byte-identity assertions still hold there, the
//! tier-counter expectations are skipped.

use kvq::coordinator::admission::{AdmissionConfig, AdmissionMode};
use kvq::coordinator::batcher::BatcherConfig;
use kvq::coordinator::engine::{self, EngineConfig};
use kvq::coordinator::request::{collect_response, FinishReason};
use kvq::coordinator::router::{RoutePolicy, Router};
use kvq::coordinator::{EngineHandle, MetricsSnapshot};
use kvq::kvcache::manager::{CacheConfig, KvCacheManager, SeqId};
use kvq::kvcache::{ColdTier, PolicySpec, Precision, PrefixCache, QuantPolicy};
use kvq::model::runner::CpuBackend;
use kvq::model::sample::SamplingParams;
use kvq::model::weights::Weights;
use kvq::model::{CpuModel, ModelSpec};
use kvq::quant::simd::{Isa, KernelBackend};
use kvq::quant::Variant;

// ---------------------------------------------------------------------------
// Manager-level: demote → promote bit-identity and COW protection
// ---------------------------------------------------------------------------

/// Decode one token through every kernel variant × {scalar, SIMD} and
/// return the raw f32 bit patterns of (logits, k_new, v_new) per pair —
/// the strictest equality the serving path can express.
fn decode_bits(
    mdl: &CpuModel,
    mgr: &KvCacheManager,
    id: SeqId,
    tok: i32,
    pos: usize,
) -> Vec<(String, Vec<u32>)> {
    let simd = KernelBackend::Simd.resolve();
    let mut out = Vec::new();
    for v in Variant::ALL {
        for isa in [Isa::Scalar, simd] {
            let view = mgr.view(id).unwrap();
            let (logits, kn, vn) = mdl.decode_paged(tok, pos, &view, v, isa).unwrap();
            let bits: Vec<u32> =
                logits.iter().chain(&kn).chain(&vn).map(|f| f.to_bits()).collect();
            out.push((format!("{v:?}/{isa:?}"), bits));
        }
    }
    out
}

fn tiny_cache_cfg(spec: &ModelSpec) -> CacheConfig {
    CacheConfig {
        layers: spec.layers,
        heads: spec.heads,
        head_dim: spec.head_dim,
        max_seq: spec.max_seq,
        block_size: 4,
        num_blocks: 256,
        scale_margin: 1.0,
    }
}

/// Runs first (libtest executes tests alphabetically): when CI reruns
/// this binary with a `KVQ_FAULT` tier_decompress error rule, this test
/// deterministically absorbs the one-shot fault so the bit-identity
/// tests below see a clean tier. Either branch is a pass: with the
/// fault armed the corrupted entry must be dropped typed (never served,
/// never panicking); without it the round-trip must succeed.
#[test]
fn a_fault_warmup_absorbs_injected_decompress_error() {
    let spec = ModelSpec::test_tiny();
    let mdl = CpuModel::new(spec.clone(), Weights::synthetic(&spec, 0xAB5));
    let cfg = tiny_cache_cfg(&spec);
    let policy = PolicySpec::uniform(Precision::Int8)
        .resolve(spec.layers, spec.heads, spec.head_dim)
        .unwrap();
    let mut mgr = KvCacheManager::new(cfg, policy);
    let mut pc = PrefixCache::new(64);
    let mut tier = ColdTier::new(256, 0); // 0 = no thread: promotion is synchronous
    let ctx = 8usize;
    let prompt: Vec<i32> = (0..ctx as i32).map(|j| (j * 5 + 11) % 64).collect();

    let pre = mdl.prefill(&prompt, ctx);
    let seq = mgr.new_sequence();
    mgr.set_prefill(seq, &pre.k, &pre.v, ctx).unwrap();
    pc.insert(&mut mgr, seq, &prompt, &pre.logits);
    mgr.free(seq);
    assert!(tier.demote_for(&mut pc, &mut mgr, u64::MAX) > 0, "entry must demote");

    match tier.promote(&mut mgr, &prompt) {
        Some((back, _logits)) => {
            // No fault armed: normal round-trip; promotion consumed the entry.
            assert!(!tier.contains(&prompt));
            mgr.free(back);
        }
        None => {
            // Injected decompress failure: the entry must be dropped
            // typed, never retried, never served corrupted.
            assert!(!tier.contains(&prompt), "failed entry must be dropped, not retried");
            assert!(
                tier.stats().decompress_errors >= 1,
                "a refused promotion must book a decompress error"
            );
        }
    }
}

#[test]
fn demote_promote_is_bit_identical_across_variants_and_isas() {
    let spec = ModelSpec::test_tiny();
    let mdl = CpuModel::new(spec.clone(), Weights::synthetic(&spec, 0x7E1));
    let cfg = tiny_cache_cfg(&spec);
    let policies: [(&str, QuantPolicy); 2] = [
        (
            "int8",
            PolicySpec::uniform(Precision::Int8)
                .resolve(spec.layers, spec.heads, spec.head_dim)
                .unwrap(),
        ),
        ("k8v4", PolicySpec::K8V4.resolve(spec.layers, spec.heads, spec.head_dim).unwrap()),
    ];
    for (name, policy) in policies {
        let mut mgr = KvCacheManager::new(cfg, policy);
        let mut pc = PrefixCache::new(64);
        let mut tier = ColdTier::new(256, 0); // 0 = no thread: promotion decompresses synchronously
        let ctx = 8usize; // two full 4-token blocks, empty tail
        let prompt: Vec<i32> = (0..ctx as i32).map(|j| (j * 5 + 11) % 64).collect();
        let tok = (ctx as i32 * 5 + 11) % 64;

        let pre = mdl.prefill(&prompt, ctx);
        let seq = mgr.new_sequence();
        mgr.set_prefill(seq, &pre.k, &pre.v, ctx).unwrap();
        pc.insert(&mut mgr, seq, &prompt, &pre.logits);
        let expect = decode_bits(&mdl, &mgr, seq, tok, ctx);
        mgr.free(seq);

        let demoted = tier.demote_for(&mut pc, &mut mgr, u64::MAX);
        assert!(demoted > 0, "{name}: reclaimable trie entry must demote");
        assert!(tier.contains(&prompt), "{name}: demoted prompt must be cold");

        let (back, logits) = tier.promote(&mut mgr, &prompt).expect("promotion must fit");
        let want: Vec<u32> = pre.logits.iter().map(|f| f.to_bits()).collect();
        let got: Vec<u32> = logits.iter().map(|f| f.to_bits()).collect();
        assert_eq!(got, want, "{name}: captured tail logits must round-trip bit-exactly");
        assert!(!tier.contains(&prompt), "{name}: promotion removes the cold entry");

        let after = decode_bits(&mdl, &mgr, back, tok, ctx);
        for ((label, want), (_, got)) in expect.iter().zip(after) {
            assert_eq!(
                got,
                *want,
                "{name}/{label}: decode over promoted blocks must be bit-identical"
            );
        }
        let s = tier.stats();
        assert_eq!(s.demotions, demoted);
        assert_eq!(s.promotions, 1);
        assert_eq!(s.prefetch_misses, 1, "no prefetch thread: promotion is synchronous");
        assert_eq!(s.cold_entries, 0);
    }
}

#[test]
fn shared_live_blocks_are_never_demoted_out_from_under_a_writer() {
    let spec = ModelSpec::test_tiny();
    let mdl = CpuModel::new(spec.clone(), Weights::synthetic(&spec, 0xC0));
    let cfg = tiny_cache_cfg(&spec);
    let policy = PolicySpec::uniform(Precision::Int8)
        .resolve(spec.layers, spec.heads, spec.head_dim)
        .unwrap();
    let mut mgr = KvCacheManager::new(cfg, policy);
    let mut pc = PrefixCache::new(64);
    let mut tier = ColdTier::new(256, 0);

    // 10 tokens at block_size 4: two full chunks plus a 2-row partial
    // tail block — the trie pins the tail block too, and a forked writer
    // appending into it is exactly the demote-then-mutate hazard.
    let plen = 10usize;
    let prompt: Vec<i32> = (0..plen as i32).map(|j| (j * 7 + 3) % 64).collect();
    let tok = |pos: usize| (pos as i32 * 7 + 3) % 64;

    let pre = mdl.prefill(&prompt, plen);
    let a = mgr.new_sequence();
    mgr.set_prefill(a, &pre.k, &pre.v, plen).unwrap();
    pc.insert(&mut mgr, a, &prompt, &pre.logits);
    let b = mgr.fork(a).unwrap();
    mgr.free(a);
    let expect = decode_bits(&mdl, &mgr, b, tok(plen), plen);

    // Every trie block is shared with the live fork: nothing is
    // reclaimable, so demotion must refuse outright.
    assert_eq!(tier.demote_for(&mut pc, &mut mgr, u64::MAX), 0);
    assert!(!tier.contains(&prompt), "shared span must stay hot");

    // The writer appends through the shared partial block. COW gives it
    // a private copy; the trie's pinned original must never see the new
    // rows.
    let simd = KernelBackend::Simd.resolve();
    for pos in plen..plen + 3 {
        let (_, kn, vn) = {
            let view = mgr.view(b).unwrap();
            mdl.decode_paged(tok(pos), pos, &view, Variant::Vectorized, simd).unwrap()
        };
        mgr.append_row(b, &kn, &vn).unwrap();
    }
    // The COW copy dropped the original tail block to pin-only refcount,
    // so the prompt may demote now — capturing the *original* rows.
    let demoted = tier.demote_for(&mut pc, &mut mgr, u64::MAX);
    assert!(demoted >= 1, "post-COW tail is reclaimable and must demote");
    assert!(tier.contains(&prompt));

    // Writer is completely unaffected by the demotion.
    let view = mgr.view(b).unwrap();
    mdl.decode_paged(tok(plen + 3), plen + 3, &view, Variant::Vectorized, simd).unwrap();
    drop(view);
    mgr.free(b);
    tier.demote_for(&mut pc, &mut mgr, u64::MAX); // drain the remaining chunks

    // The promoted copy restores the prompt exactly as captured — the
    // writer's appended rows never leaked into the cold bytes.
    let (c, _) = tier.promote(&mut mgr, &prompt).expect("promotion must fit");
    let after = decode_bits(&mdl, &mgr, c, tok(plen), plen);
    for ((label, want), (_, got)) in expect.iter().zip(after) {
        assert_eq!(got, *want, "{label}: promoted prompt must predate the writer's mutation");
    }
}

// ---------------------------------------------------------------------------
// Engine-level: serving identity under pressure + snapshot round-trip
// ---------------------------------------------------------------------------

fn cpu_factory() -> impl FnOnce() -> anyhow::Result<Box<dyn kvq::model::LmBackend>> + Send {
    || {
        let spec = ModelSpec::test_tiny();
        let w = Weights::synthetic(&spec, 7);
        Ok(Box::new(CpuBackend::new(spec, w)) as Box<dyn kvq::model::LmBackend>)
    }
}

/// True when an env override forces the tier off: the CI tier-off job
/// sets `KVQ_COLD_TIER=off`, and the cache-off job's
/// `KVQ_PREFIX_CACHE_BLOCKS=0` disables the tier transitively (it only
/// engages when the prefix cache is enabled). Identity assertions still
/// hold; tier-counter expectations are skipped.
fn tier_forced_off() -> bool {
    matches!(std::env::var("KVQ_COLD_TIER").as_deref(), Ok("off") | Ok("0"))
        || std::env::var("KVQ_PREFIX_CACHE_BLOCKS").as_deref() == Ok("0")
}

fn tier_engine(
    num_blocks: Option<usize>,
    prefix_blocks: usize,
    cold_blocks: usize,
    snapshot: Option<String>,
    max_prefills: usize,
) -> (EngineHandle, std::thread::JoinHandle<()>) {
    let cfg = EngineConfig {
        quant_policy: PolicySpec::uniform(Precision::Int8),
        num_blocks,
        prefix_cache_blocks: prefix_blocks,
        cold_tier_blocks: Some(cold_blocks),
        snapshot_path: snapshot,
        prefetch_depth: 2,
        batcher: BatcherConfig {
            max_prefills_per_step: max_prefills,
            admission: AdmissionConfig {
                mode: AdmissionMode::Optimistic,
                max_running: 8,
                ..Default::default()
            },
            ..Default::default()
        },
        ..Default::default()
    };
    engine::spawn(cfg, cpu_factory())
}

fn run_requests(
    h: &EngineHandle,
    prompts: &[Vec<i32>],
    max_new: usize,
    concurrent: bool,
) -> Vec<Vec<i32>> {
    let mut router = Router::new(RoutePolicy::RoundRobin);
    router.add_engine("e", h.clone());
    if concurrent {
        let streams: Vec<_> = prompts
            .iter()
            .map(|p| router.submit(p.clone(), max_new, SamplingParams::default()).unwrap().1)
            .collect();
        streams
            .iter()
            .map(|rx| {
                let (tokens, reason, ..) = collect_response(rx);
                assert_eq!(reason, FinishReason::Length, "request must finish");
                tokens
            })
            .collect()
    } else {
        prompts
            .iter()
            .map(|p| {
                let (_, rx) =
                    router.submit(p.clone(), max_new, SamplingParams::default()).unwrap();
                let (tokens, reason, ..) = collect_response(&rx);
                assert_eq!(reason, FinishReason::Length);
                tokens
            })
            .collect()
    }
}

fn drain(h: EngineHandle, join: std::thread::JoinHandle<()>) -> MetricsSnapshot {
    h.drain();
    join.join().unwrap();
    h.metrics.snapshot()
}

#[test]
fn constrained_pool_with_tier_on_is_token_identical_and_absorbs_pressure() {
    // test-tiny: block=8, max_seq=32. 24-token prompts + 8 new tokens
    // fill a sequence (16 blocks); two warm prompts pin 24 of the
    // 40-block pool, so a concurrent pair of fresh prompts forces the
    // pressure valve through the warm trie in every interleaving.
    let spec = ModelSpec::test_tiny();
    let prompt_len = 3 * spec.block_size;
    let max_new = spec.max_seq - prompt_len;
    let num_blocks = 2 * spec.layers * spec.max_seq.div_ceil(spec.block_size) * 5 / 2;
    let mk = |tag: i32| -> Vec<i32> {
        (0..prompt_len as i32).map(|j| (tag * 13 + j * 5 + 2) % spec.vocab as i32).collect()
    };
    let warm = vec![mk(1), mk(2)];
    let fresh = vec![mk(3), mk(4)];

    let run = |blocks: Option<usize>, prefix: usize, cold: usize| {
        let (h, join) = tier_engine(blocks, prefix, cold, None, 2);
        let mut out = run_requests(&h, &warm, max_new, false);
        out.extend(run_requests(&h, &fresh, max_new, true));
        out.extend(run_requests(&h, &warm, max_new, false));
        (out, drain(h, join))
    };

    let (expect, m) = run(None, 0, 0); // unconstrained, no caching at all
    assert_eq!(m.preemptions, 0, "reference must be uncontended");
    let (got_off, m_off) = run(Some(num_blocks), 64, 0);
    let (got_on, m_on) = run(Some(num_blocks), 64, num_blocks);

    assert_eq!(got_off, expect, "constrained tier-off run must be token-identical");
    assert_eq!(got_on, expect, "constrained tier-on run must be token-identical");

    let env = std::env::var("KVQ_COLD_TIER").ok();
    if env.is_none() || matches!(env.as_deref(), Some("off") | Some("0")) {
        assert_eq!(m_off.tier.demotions, 0, "cold_tier_blocks=0 must never demote");
    }
    if !tier_forced_off() {
        assert!(m_on.tier.demotions > 0, "warm trie must demote under pressure");
        assert!(
            m_on.tier.preemptions_avoided > 0,
            "demotion must absorb at least one pool-pressure reclaim"
        );
        assert!(m_on.tier.promotions > 0, "warm repeats must promote from the cold tier");
    }
}

#[test]
fn snapshot_round_trips_across_engine_restart() {
    let path = std::env::temp_dir()
        .join(format!("kvq_tiered_cache_snapshot_{}.snap", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let path_str = path.to_string_lossy().into_owned();

    let prompts: Vec<Vec<i32>> = (5..7i32)
        .map(|tag| (0..16).map(|j| (tag * 9 + j * 5 + 1) % 64).collect())
        .collect();
    let max_new = 8;

    // First engine: serve the corpus, then drain — exit demotes the hot
    // trie into the tier and writes the snapshot.
    let (h, join) = tier_engine(None, 64, 64, Some(path_str.clone()), 1);
    let first = run_requests(&h, &prompts, max_new, false);
    drain(h, join);
    if !tier_forced_off() {
        assert!(path.exists(), "drain must write the snapshot file");
    }

    // Second engine, same path: repeats are token-identical, and come
    // from restored-then-promoted entries rather than blind prefill.
    let (h, join) = tier_engine(None, 64, 64, Some(path_str), 1);
    let second = run_requests(&h, &prompts, max_new, false);
    let m = drain(h, join);
    let _ = std::fs::remove_file(&path);

    assert_eq!(second, first, "restart must not change a single token");
    if !tier_forced_off() {
        assert_eq!(
            m.tier.snapshot_loaded,
            prompts.len() as u64,
            "every persisted prompt must restore at startup"
        );
        assert_eq!(
            m.tier.promotions,
            prompts.len() as u64,
            "every repeat must be served by promotion"
        );
        assert_eq!(m.prefill_tokens, 0, "promoted prompts run zero backend prefill");
    }
}
