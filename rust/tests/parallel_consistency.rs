//! Parallelism never changes bits — the contract of the shared parallel
//! runtime (`kvq::parallel`), asserted end-to-end:
//!
//! * quantize/dequantize/scales: parallel == serial, exactly, across the
//!   thread sweep {1, 2, 8} (including NaN-bearing inputs);
//! * KvCacheManager: parallel prefill + gather store/return exactly the
//!   serial bytes, with the fan-out threshold forced to 0 so the parallel
//!   code path actually runs on test-sized inputs;
//! * Engine: greedy generations are identical at parallelism 1 and 8
//!   (decode waves reorder gathers, never outputs);
//! * Paged fused decode: bit-identical to the staged `decode_i8` path
//!   across all four attention-kernel variants and thread counts 1/2/8
//!   (the §7.5 cross-kernel consistency check, extended to the zero-copy
//!   serving path);
//! * Batched decode: `decode_batching=auto` (fused multi-query waves,
//!   COW-shared prefix blocks dequantized once per wave) emits exactly
//!   the per-sequence token streams, across all four variants, both
//!   kernel backends, threads {1, 2, 8}, paged and staged.

use kvq::coordinator::engine::{self, DecodeBatching, EngineConfig};
use kvq::coordinator::request::collect_response;
use kvq::coordinator::router::{RoutePolicy, Router};
use kvq::kvcache::manager::{CacheConfig, KvCacheManager};
use kvq::kvcache::{PolicySpec, Precision, QuantPolicy};
use kvq::model::runner::CpuBackend;
use kvq::model::sample::SamplingParams;
use kvq::model::weights::Weights;
use kvq::model::{CpuModel, ModelSpec};
use kvq::quant::simd::{self, KernelBackend};
use kvq::quant::{self, Fp32Matrix, Int8Matrix, Variant};

const SWEEP: [usize; 3] = [1, 2, 8];

#[test]
fn quantize_parallel_matches_serial_across_threads() {
    // Odd shapes exercise remainder rows/chunks.
    for (rows, cols, seed) in [(1, 1, 1u64), (7, 5, 2), (97, 53, 3), (513, 129, 4)] {
        let k = Fp32Matrix::random_normal(rows, cols, 1.0, seed);
        let s = quant::compute_scales(&k);
        let mut base = Int8Matrix::zeros(rows, cols);
        quant::quantize::quantize_naive(&k, &s, &mut base);
        for threads in SWEEP {
            let mut par = Int8Matrix::zeros(rows, cols);
            quant::quantize_parallel(&k, &s, &mut par, threads);
            assert_eq!(par.data, base.data, "{rows}x{cols} x{threads}");
            assert_eq!(par.scales, base.scales);
        }
    }
}

#[test]
fn dequantize_parallel_matches_serial_across_threads() {
    for (rows, cols, seed) in [(1, 3, 5u64), (64, 16, 6), (301, 41, 7)] {
        let k = Fp32Matrix::random_uniform(rows, cols, -2.0, 2.0, seed);
        let q = quant::quantize_fused(&k);
        let serial = quant::dequantize(&q);
        for threads in SWEEP {
            let mut par = Fp32Matrix::zeros(rows, cols);
            quant::dequantize_parallel(&q, &mut par, threads);
            let bits = |x: &[f32]| x.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&par.data), bits(&serial.data), "{rows}x{cols} x{threads}");
        }
    }
}

#[test]
fn scales_parallel_matches_serial_across_threads() {
    let k = Fp32Matrix::random_normal(257, 63, 1.0, 8);
    let mut serial = vec![0.0f32; k.cols];
    quant::scales::compute_scales_rowsweep(&k, &mut serial);
    for threads in SWEEP {
        let mut par = vec![0.0f32; k.cols];
        quant::scales::compute_scales_parallel(&k, &mut par, threads);
        assert_eq!(par, serial, "x{threads}");
    }
}

#[test]
fn nan_inputs_identical_across_all_paths() {
    // The pinned NaN→0 behavior must hold on the parallel paths too.
    let mut k = Fp32Matrix::random_uniform(65, 19, -1.0, 1.0, 9);
    k.data[0] = f32::NAN;
    k.data[700] = f32::NAN;
    let s = quant::compute_scales(&k);
    assert!(s.iter().all(|v| v.is_finite()));
    let mut base = Int8Matrix::zeros(k.rows, k.cols);
    quant::quantize::quantize_naive(&k, &s, &mut base);
    assert_eq!(base.data[0], 0);
    assert_eq!(base.data[700], 0);
    for threads in SWEEP {
        let mut par = Int8Matrix::zeros(k.rows, k.cols);
        quant::quantize_parallel(&k, &s, &mut par, threads);
        assert_eq!(par.data, base.data, "x{threads}");
    }
}

fn cache_cfg() -> CacheConfig {
    CacheConfig {
        layers: 3,
        heads: 2,
        head_dim: 8,
        max_seq: 48,
        block_size: 4,
        num_blocks: 512,
        scale_margin: 1.0,
    }
}

fn cache_mgr(c: CacheConfig, precision: Precision) -> KvCacheManager {
    KvCacheManager::new(c, QuantPolicy::uniform(precision, c.layers, c.heads))
}

fn prefill_tensors(c: &CacheConfig, len: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let n = c.layers * c.heads * c.max_seq * c.head_dim;
    let mut rng = kvq::util::rng::Rng::new(seed);
    let mut k = vec![0.0f32; n];
    let mut v = vec![0.0f32; n];
    for layer in 0..c.layers {
        for head in 0..c.heads {
            for t in 0..len {
                for ch in 0..c.head_dim {
                    let i = ((layer * c.heads + head) * c.max_seq + t) * c.head_dim + ch;
                    k[i] = rng.uniform(-1.0, 1.0);
                    v[i] = rng.uniform(-1.0, 1.0);
                }
            }
        }
    }
    (k, v)
}

#[test]
fn cache_manager_parallel_prefill_gather_identical() {
    for precision in [Precision::Int8, Precision::Fp32] {
        // Lengths covering: one block, partial tail, exact block multiple.
        for len in [3usize, 17, 32] {
            let c = cache_cfg();
            let (k, v) = prefill_tensors(&c, len, 0xC0FE ^ len as u64);

            let mut serial = cache_mgr(c, precision);
            let sid = serial.new_sequence();
            serial.set_prefill(sid, &k, &v, len).unwrap();

            for threads in SWEEP {
                let mut par = cache_mgr(c, precision);
                par.set_parallelism(threads);
                par.set_parallel_threshold(0); // force fan-out at test size
                let pid = par.new_sequence();
                par.set_prefill(pid, &k, &v, len).unwrap();

                let n = c.heads * c.max_seq * c.head_dim;
                for layer in 0..c.layers {
                    for kv in 0..2 {
                        assert_eq!(
                            serial.scales(sid, layer, kv).unwrap(),
                            par.scales(pid, layer, kv).unwrap(),
                            "scales len={len} x{threads} layer={layer} kv={kv}"
                        );
                        if precision == Precision::Int8 {
                            let mut a = vec![0i8; n];
                            let mut b = vec![0i8; n];
                            serial.gather_i8(sid, layer, kv, &mut a).unwrap();
                            par.gather_i8(pid, layer, kv, &mut b).unwrap();
                            assert_eq!(a, b, "i8 len={len} x{threads} l={layer} kv={kv}");
                        } else {
                            let mut a = vec![0f32; n];
                            let mut b = vec![0f32; n];
                            serial.gather_f32(sid, layer, kv, &mut a).unwrap();
                            par.gather_f32(pid, layer, kv, &mut b).unwrap();
                            let bits =
                                |x: &[f32]| x.iter().map(|y| y.to_bits()).collect::<Vec<_>>();
                            assert_eq!(bits(&a), bits(&b), "f32 len={len} x{threads}");
                        }
                    }
                }
            }
        }
    }
}

fn cpu_factory() -> impl FnOnce() -> anyhow::Result<Box<dyn kvq::model::LmBackend>> + Send {
    || {
        let spec = ModelSpec::test_tiny();
        let w = Weights::synthetic(&spec, 7);
        Ok(Box::new(CpuBackend::new(spec, w)) as Box<dyn kvq::model::LmBackend>)
    }
}

#[test]
fn engine_generations_identical_across_parallelism() {
    // Same prompts, greedy sampling: the token streams must match between
    // a serial engine and one running decode waves with 8 workers.
    let gen_tokens = |parallelism: usize| -> Vec<Vec<i32>> {
        let cfg = EngineConfig {
            quant_policy: PolicySpec::uniform(Precision::Int8),
            parallelism,
            ..Default::default()
        };
        let (h, join) = engine::spawn(cfg, cpu_factory());
        let mut router = Router::new(RoutePolicy::RoundRobin);
        router.add_engine("int8", h.clone());
        let mut streams = Vec::new();
        for i in 0..5 {
            let prompt = vec![i as i32 + 1, 7, 9, 2];
            let (_, rx) = router.submit(prompt, 6, SamplingParams::default()).unwrap();
            streams.push(rx);
        }
        let out: Vec<Vec<i32>> =
            streams.iter().map(|rx| collect_response(rx).0).collect();
        h.drain();
        join.join().unwrap();
        out
    };
    let serial = gen_tokens(1);
    let parallel = gen_tokens(8);
    assert_eq!(serial, parallel, "decode waves changed generated tokens");
    assert!(serial.iter().all(|t| t.len() == 6));
}

#[test]
fn paged_decode_bit_identical_to_staged_across_variants_and_threads() {
    // Same model, same prompt: decode one token over (a) the legacy dense
    // staging gathered out of the cache and (b) the zero-copy paged view,
    // across every attention-kernel variant and manager thread count.
    // Logits and K/V rows must match bit-for-bit.
    let spec = ModelSpec::test_tiny();
    let model = CpuModel::new(spec.clone(), Weights::synthetic(&spec, 7));
    let mut rng = kvq::util::rng::Rng::new(13);
    let tokens: Vec<i32> = (0..20).map(|_| rng.below(spec.vocab as u64) as i32).collect();
    let (l, h, s, d) = (spec.layers, spec.heads, spec.max_seq, spec.head_dim);

    // Lengths covering a partial tail block and an exact block multiple.
    for n in [5usize, 16] {
        let pre = model.prefill(&tokens, n);
        for threads in SWEEP {
            let cfg = CacheConfig {
                layers: l,
                heads: h,
                head_dim: d,
                max_seq: s,
                block_size: spec.block_size,
                num_blocks: 256,
                scale_margin: 1.0,
            };
            let mut mgr = cache_mgr(cfg, Precision::Int8);
            mgr.set_parallelism(threads);
            mgr.set_parallel_threshold(0);
            let id = mgr.new_sequence();
            mgr.set_prefill(id, &pre.k, &pre.v, n).unwrap();

            // Staged path: gather the full dense staging + per-block
            // scales. The manager stores scales block-major
            // `[bi][head][ch]`; the staged ABI wants `(L, H, B, d)` with
            // B derived from max_seq — transpose the allocated blocks
            // and leave never-allocated block grids zero.
            let nb = s.div_ceil(spec.block_size);
            let mut kq = vec![0i8; l * h * s * d];
            let mut vq = vec![0i8; l * h * s * d];
            let mut ks = vec![0.0f32; l * h * nb * d];
            let mut vs = vec![0.0f32; l * h * nb * d];
            for layer in 0..l {
                let span = layer * h * s * d..(layer + 1) * h * s * d;
                mgr.gather_i8(id, layer, 0, &mut kq[span.clone()]).unwrap();
                mgr.gather_i8(id, layer, 1, &mut vq[span]).unwrap();
                for (kv, dst) in [(0usize, &mut ks), (1, &mut vs)] {
                    let src = mgr.scales(id, layer, kv).unwrap();
                    let lbase = layer * h * nb * d;
                    for bi in 0..src.len() / (h * d) {
                        for head in 0..h {
                            let to = lbase + (head * nb + bi) * d;
                            let from = (bi * h + head) * d;
                            dst[to..to + d].copy_from_slice(&src[from..from + d]);
                        }
                    }
                }
            }
            // Staged and paged must agree under whichever backend the
            // session resolves (per-backend bit-stability: both paths run
            // the same kernels; partitioning into blocks never changes
            // per-row dots or row-ascending accumulation).
            let isa = simd::default_isa();
            let (sl, sk, sv) = model.decode_i8(tokens[n], n, &kq, &ks, &vq, &vs, isa);

            let bits = |x: &[f32]| x.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
            for variant in Variant::ALL {
                let view = mgr.view(id).unwrap();
                let (pl, pk, pv) =
                    model.decode_paged(tokens[n], n, &view, variant, isa).unwrap();
                assert_eq!(bits(&pl), bits(&sl), "logits diverged: n={n} x{threads} {variant:?}");
                assert_eq!(bits(&pk), bits(&sk), "k_new diverged: n={n} {variant:?}");
                assert_eq!(bits(&pv), bits(&sv), "v_new diverged: n={n} {variant:?}");
            }
            mgr.free(id);
        }
    }
}

#[test]
fn engine_paged_and_staged_generations_identical() {
    // Full engine runs: the zero-copy paged data path (every kernel
    // variant) must emit exactly the token streams of the staged path,
    // at thread counts 1/2/8.
    let gen_tokens = |paged: bool, kernel: Variant, parallelism: usize| -> Vec<Vec<i32>> {
        let cfg = EngineConfig {
            quant_policy: PolicySpec::uniform(Precision::Int8),
            parallelism,
            paged_decode: paged,
            attention_kernel: kernel,
            ..Default::default()
        };
        let (h, join) = engine::spawn(cfg, cpu_factory());
        let mut router = Router::new(RoutePolicy::RoundRobin);
        router.add_engine("int8", h.clone());
        let mut streams = Vec::new();
        for i in 0..4 {
            let prompt = vec![i as i32 + 1, 11, 3, 5];
            let (_, rx) = router.submit(prompt, 5, SamplingParams::default()).unwrap();
            streams.push(rx);
        }
        let out: Vec<Vec<i32>> = streams.iter().map(|rx| collect_response(rx).0).collect();
        h.drain();
        join.join().unwrap();
        out
    };
    let staged = gen_tokens(false, Variant::Vectorized, 1);
    for threads in SWEEP {
        for kernel in Variant::ALL {
            let paged = gen_tokens(true, kernel, threads);
            assert_eq!(
                staged, paged,
                "paged decode changed generated tokens ({kernel:?} x{threads})"
            );
        }
    }
    assert!(staged.iter().all(|t| t.len() == 5));
}

#[test]
fn uniform_policy_presets_bit_identical_across_kernels_and_threads() {
    // The uniform:* presets ARE the legacy --precision paths. For each of
    // fp32/int8/int4, engines must emit identical token streams across
    // all four attention kernels and threads {1, 2, 8}; the staging-
    // capable presets (fp32/int8) must also match their legacy staged
    // (dense artifact-layout) path bit-for-bit.
    let run = |policy: PolicySpec, paged: bool, kernel: Variant, threads: usize| {
        let cfg = EngineConfig {
            quant_policy: policy,
            paged_decode: paged,
            attention_kernel: kernel,
            parallelism: threads,
            ..Default::default()
        };
        let (h, join) = engine::spawn(cfg, cpu_factory());
        let mut router = Router::new(RoutePolicy::RoundRobin);
        router.add_engine("eng", h.clone());
        let mut streams = Vec::new();
        for i in 0..3 {
            let prompt = vec![i as i32 + 2, 9, 4];
            let (_, rx) = router.submit(prompt, 4, SamplingParams::default()).unwrap();
            streams.push(rx);
        }
        let out: Vec<Vec<i32>> = streams.iter().map(|rx| collect_response(rx).0).collect();
        h.drain();
        join.join().unwrap();
        out
    };
    for precision in [Precision::Fp32, Precision::Int8, Precision::Int4] {
        let policy = PolicySpec::uniform(precision);
        let reference = run(policy.clone(), true, Variant::Vectorized, 1);
        assert!(reference.iter().all(|t| t.len() == 4), "{precision:?} runs end-to-end");
        for threads in SWEEP {
            for kernel in Variant::ALL {
                assert_eq!(
                    run(policy.clone(), true, kernel, threads),
                    reference,
                    "uniform:{precision:?} diverged ({kernel:?} x{threads})"
                );
            }
        }
        if precision != Precision::Int4 {
            assert_eq!(
                run(policy.clone(), false, Variant::Vectorized, 1),
                reference,
                "uniform:{precision:?} staged path diverged from paged"
            );
        }
    }
}

#[test]
fn uniform_policy_metrics_pin_the_legacy_cache_byte_formulas() {
    // `GET /metrics` cache byte counts for the uniform presets must equal
    // the closed forms under per-block scale grids: a staged decode step
    // books 2·bytes(L·H·S·d) payload + 2·L·H·B·d·4 scale bytes
    // (B = ceil(max_seq / block_size) staged grid blocks); a paged step
    // books the O(len) in-place read volume with one H·d·4 grid per
    // *touched* block per stream. One deterministic request (prompt 3,
    // max_new 4 → decode steps at pos 3, 4, 5) pins both.
    let spec = ModelSpec::test_tiny();
    let (l, h, d, s) = (spec.layers, spec.heads, spec.head_dim, spec.max_seq);
    let bs = spec.block_size;
    let run = |paged: bool| {
        let cfg = EngineConfig {
            quant_policy: PolicySpec::uniform(Precision::Int8),
            paged_decode: paged,
            ..Default::default()
        };
        let (hdl, join) = engine::spawn(cfg, cpu_factory());
        let mut router = Router::new(RoutePolicy::RoundRobin);
        router.add_engine("int8", hdl.clone());
        let (_, rx) = router.submit(vec![1, 2, 3], 4, SamplingParams::default()).unwrap();
        let (tokens, ..) = collect_response(&rx);
        assert_eq!(tokens.len(), 4);
        hdl.drain();
        join.join().unwrap();
        hdl.metrics.snapshot()
    };

    let staged = run(false);
    assert_eq!(staged.decode_steps, 3);
    let staged_step = 2 * (l * h * s * d) + 2 * (l * h * s.div_ceil(bs) * d * 4);
    assert_eq!(staged.cache_bytes_read, (3 * staged_step) as u64, "staged formula");

    let paged = run(true);
    assert_eq!(paged.decode_steps, 3);
    assert_eq!(paged.policy, "uniform:int8", "policy name surfaces in metrics");
    let per_pos = |pos: usize| 2 * l * (h * pos * d + pos.div_ceil(bs) * h * d * 4);
    let want: usize = [3usize, 4, 5].iter().map(|&p| per_pos(p)).sum();
    assert_eq!(paged.cache_bytes_read, want as u64, "paged O(len) formula");
}

#[test]
fn mixed_policy_generations_deterministic_across_kernels_and_threads() {
    // k8v4 and sink8 have no legacy twin, but the same invariant must
    // hold: kernel variant and parallelism never change generated tokens.
    for policy in [PolicySpec::K8V4, PolicySpec::Sink8 { sink_layers: 1 }] {
        let run = |kernel: Variant, threads: usize| {
            let cfg = EngineConfig {
                quant_policy: policy.clone(),
                parallelism: threads,
                attention_kernel: kernel,
                ..Default::default()
            };
            let (h, join) = engine::spawn(cfg, cpu_factory());
            let mut router = Router::new(RoutePolicy::RoundRobin);
            router.add_engine("eng", h.clone());
            let (_, rx) = router.submit(vec![5, 1, 7], 5, SamplingParams::default()).unwrap();
            let out = collect_response(&rx).0;
            h.drain();
            join.join().unwrap();
            out
        };
        let reference = run(Variant::Vectorized, 1);
        assert_eq!(reference.len(), 5, "{} serves end-to-end", policy.name());
        for threads in SWEEP {
            for kernel in Variant::ALL {
                assert_eq!(
                    run(kernel, threads),
                    reference,
                    "{} diverged ({kernel:?} x{threads})",
                    policy.name()
                );
            }
        }
    }
}

#[test]
fn simd_backend_tokens_byte_identical_across_threads_and_reruns() {
    // The per-backend contract of the kernel_backend knob: same backend +
    // same threads => byte-identical tokens, and the thread count never
    // changes tokens either (decode order is unchanged; gathers are
    // read-only). On hosts without SIMD the knob degrades to scalar and
    // this pins the fallback instead.
    let run = |threads: usize| -> Vec<Vec<i32>> {
        let cfg = EngineConfig {
            quant_policy: PolicySpec::uniform(Precision::Int8),
            kernel_backend: KernelBackend::Simd,
            parallelism: threads,
            ..Default::default()
        };
        let (h, join) = engine::spawn(cfg, cpu_factory());
        let mut router = Router::new(RoutePolicy::RoundRobin);
        router.add_engine("simd", h.clone());
        let mut streams = Vec::new();
        for i in 0..4 {
            let prompt = vec![i as i32 + 3, 8, 1, 6];
            let (_, rx) = router.submit(prompt, 6, SamplingParams::default()).unwrap();
            streams.push(rx);
        }
        let out: Vec<Vec<i32>> = streams.iter().map(|rx| collect_response(rx).0).collect();
        h.drain();
        join.join().unwrap();
        out
    };
    let reference = run(1);
    assert!(reference.iter().all(|t| t.len() == 6));
    for threads in SWEEP {
        assert_eq!(run(threads), reference, "simd backend diverged at x{threads}");
    }
    // Determinism across reruns at the same thread count.
    assert_eq!(run(1), reference, "simd backend not deterministic across runs");
}

#[test]
fn staged_and_paged_agree_under_forced_simd_backend() {
    // The staged==paged bit-identity must hold per backend, not just for
    // scalar: both paths route through the same ISA kernels, and block
    // partitioning is invariant for per-row dots and row-ascending
    // accumulation.
    let run = |paged: bool| -> Vec<Vec<i32>> {
        let cfg = EngineConfig {
            quant_policy: PolicySpec::uniform(Precision::Int8),
            kernel_backend: KernelBackend::Simd,
            paged_decode: paged,
            ..Default::default()
        };
        let (h, join) = engine::spawn(cfg, cpu_factory());
        let mut router = Router::new(RoutePolicy::RoundRobin);
        router.add_engine("eng", h.clone());
        let mut streams = Vec::new();
        for i in 0..3 {
            let prompt = vec![i as i32 + 1, 12, 5];
            let (_, rx) = router.submit(prompt, 5, SamplingParams::default()).unwrap();
            streams.push(rx);
        }
        let out: Vec<Vec<i32>> = streams.iter().map(|rx| collect_response(rx).0).collect();
        h.drain();
        join.join().unwrap();
        out
    };
    assert_eq!(run(false), run(true), "staged vs paged diverged under the simd backend");
}

/// Spawn one engine with the given decode-batching knob and serve a
/// COW-shared-prefix wave: two distinct two-block prompts that share
/// their first block, each submitted twice, with the prefix cache on.
/// The repeats are exact trie hits; the cross-prompt shared first block
/// is a *partial* hit (suffix prefill over the second block only), so
/// decode waves reference physical prefix blocks shared across all four
/// members — each carrying its own frozen per-block scale grid.
/// Returns the token streams and the end-of-run metrics snapshot.
fn batched_wave_run(
    batching: DecodeBatching,
    paged: bool,
    kernel: Variant,
    kb: KernelBackend,
    threads: usize,
) -> (Vec<Vec<i32>>, kvq::coordinator::MetricsSnapshot) {
    let cfg = EngineConfig {
        quant_policy: PolicySpec::uniform(Precision::Int8),
        decode_batching: batching,
        paged_decode: paged,
        attention_kernel: kernel,
        kernel_backend: kb,
        parallelism: threads,
        prefix_cache_blocks: 64,
        ..Default::default()
    };
    let (h, join) = engine::spawn(cfg, cpu_factory());
    let mut router = Router::new(RoutePolicy::RoundRobin);
    router.add_engine("eng", h.clone());
    // Block-multiple prompts (len == 2·block_size) so forked prefix
    // blocks stay physically shared through decode (appends COW only the
    // tail block). Block 0 is common to both prompts; block 1 differs —
    // the second prompt partially hits the first one's trie entry.
    let spec = ModelSpec::test_tiny();
    let base: Vec<Vec<i32>> = (0..2)
        .map(|p| {
            let shared = (0..spec.block_size).map(|t| t as i32 + 1);
            let own = (0..spec.block_size).map(|t| (p * 13 + t + 2) as i32);
            shared.chain(own).collect()
        })
        .collect();
    let streams: Vec<_> = (0..4)
        .map(|i| {
            router.submit(base[i % 2].clone(), 6, SamplingParams::default()).unwrap().1
        })
        .collect();
    let out: Vec<Vec<i32>> = streams.iter().map(|rx| collect_response(rx).0).collect();
    h.drain();
    join.join().unwrap();
    (out, h.metrics.snapshot())
}

#[test]
fn batched_decode_tokens_identical_to_per_sequence() {
    // The tentpole contract: regrouping a decode wave into fused
    // multi-query per-(layer, head) passes never changes a single token,
    // for every attention-kernel variant, both kernel backends, and
    // every thread count — on a wave whose members share COW prefix
    // blocks (the case the dedup actually fires on).
    // KVQ_DECODE_BATCHING=off (the CI forced-off job) downgrades `auto`
    // to the per-sequence path; the equality still must hold, the
    // mq-engagement assertions are skipped.
    let env_off = std::env::var("KVQ_DECODE_BATCHING").as_deref() == Ok("off");
    for kb in [KernelBackend::Scalar, KernelBackend::Simd] {
        for threads in SWEEP {
            for kernel in Variant::ALL {
                let (off, off_snap) =
                    batched_wave_run(DecodeBatching::Off, true, kernel, kb, threads);
                let (auto, auto_snap) =
                    batched_wave_run(DecodeBatching::Auto, true, kernel, kb, threads);
                assert_eq!(
                    off, auto,
                    "batched decode changed tokens ({kernel:?} {kb:?} x{threads})"
                );
                assert_eq!(off_snap.mq_passes, 0, "off must never take the mq path");
                if !env_off {
                    assert!(
                        auto_snap.mq_passes > 0,
                        "auto must take the mq path on a concurrent wave \
                         ({kernel:?} {kb:?} x{threads})"
                    );
                    assert!(
                        auto_snap.cache_bytes_read <= off_snap.cache_bytes_read,
                        "shared-prefix wave must not read more bytes batched \
                         ({kernel:?} {kb:?} x{threads})"
                    );
                }
                assert!(off.iter().all(|t| t.len() == 6));
            }
        }
    }
}

#[test]
fn batched_decode_dedups_shared_prefix_blocks() {
    // Duplicate prompts fork the prefix cache, so the wave's members
    // reference the same physical prefix block — the batched path must
    // report dedup (each shared block decoded once per wave) and a
    // strictly smaller cache read volume than per-sequence.
    if std::env::var("KVQ_DECODE_BATCHING").as_deref() == Ok("off") {
        return; // forced-off CI job: the mq path is intentionally disabled
    }
    if std::env::var("KVQ_PREFIX_CACHE_BLOCKS").as_deref() == Ok("0") {
        return; // cache-off CI job: no COW sharing, nothing to dedup
    }
    let (_, off) =
        batched_wave_run(DecodeBatching::Off, true, Variant::Vectorized, KernelBackend::Scalar, 1);
    let (_, auto) =
        batched_wave_run(DecodeBatching::Auto, true, Variant::Vectorized, KernelBackend::Scalar, 1);
    assert!(auto.blocks_deduped > 0, "COW-shared prefix blocks must dedup in the wave");
    assert!(
        auto.cache_bytes_read < off.cache_bytes_read,
        "deduped waves must read strictly fewer cache bytes \
         ({} vs {})",
        auto.cache_bytes_read,
        off.cache_bytes_read
    );
    assert_eq!(off.blocks_deduped, 0);
}

#[test]
fn batched_decode_knob_is_inert_on_the_staged_path() {
    // Staged decode has no wave view; `auto` must quietly stay on the
    // legacy path (no mq passes) and emit identical tokens.
    let (off, _) =
        batched_wave_run(DecodeBatching::Off, false, Variant::Vectorized, KernelBackend::Scalar, 1);
    let (auto, snap) = batched_wave_run(
        DecodeBatching::Auto,
        false,
        Variant::Vectorized,
        KernelBackend::Scalar,
        1,
    );
    assert_eq!(off, auto, "staged path must ignore decode_batching");
    assert_eq!(snap.mq_passes, 0, "staged path must never take the mq path");
}

#[test]
fn scalar_backend_serves_deterministically() {
    // kernel_backend=scalar: determinism across reruns at the engine
    // level. (Byte-identity of Isa::Scalar to the pre-backend kernels is
    // pinned where it is actually observable: the simd module's
    // scalar-dispatch unit test asserts bit-for-bit delegation to the
    // legacy kernels, and the CI job that forces KVQ_KERNEL_BACKEND=scalar
    // reruns every legacy bit-identity test in this file through the
    // scalar dispatch path.)
    let run = |kb: KernelBackend| -> Vec<i32> {
        let cfg = EngineConfig {
            quant_policy: PolicySpec::uniform(Precision::Int8),
            kernel_backend: kb,
            ..Default::default()
        };
        let (h, join) = engine::spawn(cfg, cpu_factory());
        let mut router = Router::new(RoutePolicy::RoundRobin);
        router.add_engine("eng", h.clone());
        let (_, rx) = router.submit(vec![2, 9, 4, 7], 6, SamplingParams::default()).unwrap();
        let out = collect_response(&rx).0;
        h.drain();
        join.join().unwrap();
        out
    };
    let a = run(KernelBackend::Scalar);
    let b = run(KernelBackend::Scalar);
    assert_eq!(a, b, "scalar backend must be deterministic");
    assert_eq!(a.len(), 6);
}
