//! Sharded routing integration: session-affinity stickiness, load-aware
//! spillover, the bounded overflow queue (typed saturation, no hangs, no
//! lost streams), and the determinism contract — an affinity-pinned
//! trace is byte-identical on 1 shard and N.
//!
//! Saturation is forced deterministically with a gated backend: prefill
//! blocks the shard's engine thread on a condvar until the test opens
//! the gate, so shard depth (and the router's view of it) is exact.

use kvq::bench::workload::{Arrivals, LengthDist, Trace, TraceConfig};
use kvq::coordinator::engine::{self, EngineConfig};
use kvq::coordinator::request::{collect_response, FinishReason};
use kvq::coordinator::router::{
    Affinity, RoutePolicy, Router, RouterConfig, SubmitError, SubmitOptions,
};
use kvq::coordinator::EngineHandle;
use kvq::kvcache::manager::CacheView;
use kvq::kvcache::{PolicySpec, Precision};
use kvq::model::runner::CpuBackend;
use kvq::model::sample::SamplingParams;
use kvq::model::weights::Weights;
use kvq::model::{DecodeResult, LmBackend, ModelSpec, PrefillResult};
use kvq::quant::simd::Isa;
use kvq::quant::Variant;
use std::sync::{Arc, Condvar, Mutex};

// ---------------------------------------------------------------------------
// Gated backend: a CPU oracle whose prefill parks on a condvar.
// ---------------------------------------------------------------------------

struct Gate(Mutex<bool>, Condvar);

impl Gate {
    fn new(open: bool) -> Arc<Gate> {
        Arc::new(Gate(Mutex::new(open), Condvar::new()))
    }

    fn open(&self) {
        *self.0.lock().unwrap() = true;
        self.1.notify_all();
    }

    fn wait(&self) {
        let mut g = self.0.lock().unwrap();
        while !*g {
            g = self.1.wait(g).unwrap();
        }
    }
}

struct GatedBackend {
    inner: CpuBackend,
    gate: Arc<Gate>,
}

impl LmBackend for GatedBackend {
    fn spec(&self) -> &ModelSpec {
        self.inner.spec()
    }

    fn prefill(&self, tokens: &[i32], len: usize) -> anyhow::Result<PrefillResult> {
        self.gate.wait();
        self.inner.prefill(tokens, len)
    }

    fn decode_i8(
        &self,
        token: i32,
        pos: usize,
        kq: &[i8],
        k_scales: &[f32],
        vq: &[i8],
        v_scales: &[f32],
        isa: Isa,
    ) -> anyhow::Result<DecodeResult> {
        self.inner.decode_i8(token, pos, kq, k_scales, vq, v_scales, isa)
    }

    fn decode_f32(
        &self,
        token: i32,
        pos: usize,
        k: &[f32],
        v: &[f32],
        isa: Isa,
    ) -> anyhow::Result<DecodeResult> {
        self.inner.decode_f32(token, pos, k, v, isa)
    }

    fn supports_paged_decode(&self) -> bool {
        self.inner.supports_paged_decode()
    }

    fn decode_paged(
        &self,
        token: i32,
        pos: usize,
        view: &CacheView,
        kernel: Variant,
        isa: Isa,
    ) -> anyhow::Result<DecodeResult> {
        self.inner.decode_paged(token, pos, view, kernel, isa)
    }
}

fn spawn_shard(gate: Option<Arc<Gate>>) -> (EngineHandle, std::thread::JoinHandle<()>) {
    engine::spawn(
        EngineConfig {
            quant_policy: PolicySpec::uniform(Precision::Int8),
            seed: 42, // identical shards: placement must not change tokens
            ..Default::default()
        },
        move || {
            let spec = ModelSpec::test_tiny();
            let w = Weights::synthetic(&spec, 7);
            let inner = CpuBackend::new(spec, w);
            Ok(match gate {
                Some(gate) => {
                    Box::new(GatedBackend { inner, gate }) as Box<dyn LmBackend>
                }
                None => Box::new(inner) as Box<dyn LmBackend>,
            })
        },
    )
}

/// A session key whose affinity hash lands on `shard` out of `n`.
fn session_for_shard(router: &Router, shard: usize, n: usize) -> String {
    for i in 0..64 {
        let s = format!("sess{i}");
        if router.home_shard(Some(&s), &[1]) == shard {
            return s;
        }
    }
    panic!("no session hashed onto shard {shard}/{n} in 64 tries");
}

fn opts(session: &str) -> SubmitOptions {
    SubmitOptions { session: Some(session.to_string()), ..Default::default() }
}

// ---------------------------------------------------------------------------
// Fault warmup (must sort alphabetically first).
// ---------------------------------------------------------------------------

/// Runs first (libtest executes tests in name order; CI passes
/// `--test-threads=1` for fault reruns). When CI re-runs this binary with
/// `KVQ_FAULT` injecting a one-shot shard panic (`count: 1`), this test
/// absorbs the fault — proving the stream still terminates typed — and
/// the rest of the suite then runs on clean engines, keeping its
/// deterministic assertions intact. Without `KVQ_FAULT` it is a plain
/// smoke test.
#[test]
fn a_fault_warmup_absorbs_injected_shard_panic() {
    let (h, j) = spawn_shard(None);
    let mut router = Router::new(RoutePolicy::RoundRobin);
    router.add_engine("warmup", h.clone());
    let (_, rx) = router.submit(vec![1, 2, 3], 2, SamplingParams::default()).unwrap();
    let (_, reason, ..) = collect_response(&rx);
    assert!(
        matches!(
            reason,
            FinishReason::Length | FinishReason::ShardFailed | FinishReason::Error(_)
        ),
        "stream must terminate typed, got {reason:?}"
    );
    h.drain();
    let _ = j.join();
}

// ---------------------------------------------------------------------------
// Affinity stickiness.
// ---------------------------------------------------------------------------

#[test]
fn session_affinity_pins_sessions_to_their_home_shard() {
    let (h0, j0) = spawn_shard(None);
    let (h1, j1) = spawn_shard(None);
    let mut router = Router::with_config(RouterConfig {
        policy: RoutePolicy::LeastLoaded,
        affinity: Affinity::Session,
        queue_depth: 0, // unbounded: home shard always wins
        overflow_depth: 4,
        default_deadline_ms: 0,
    });
    router.add_engine("shard0", h0.clone());
    router.add_engine("shard1", h1.clone());

    let s0 = session_for_shard(&router, 0, 2);
    let s1 = session_for_shard(&router, 1, 2);
    let mut streams = Vec::new();
    for s in [&s0, &s1] {
        for _ in 0..3 {
            let (_, rx) = router
                .submit_with(vec![1, 2, 3], 4, SamplingParams::default(), opts(s))
                .unwrap();
            streams.push(rx);
        }
    }
    for rx in &streams {
        let (tokens, reason, ..) = collect_response(rx);
        assert!(matches!(reason, FinishReason::Length), "{reason:?}");
        assert_eq!(tokens.len(), 4);
    }
    // Every request landed on its session's home shard — stickiness.
    assert_eq!(h0.metrics.snapshot().requests_submitted, 3);
    assert_eq!(h1.metrics.snapshot().requests_submitted, 3);
    assert_eq!(router.stats().spillovers, 0);
    h0.drain();
    h1.drain();
    j0.join().unwrap();
    j1.join().unwrap();
}

// ---------------------------------------------------------------------------
// Spillover.
// ---------------------------------------------------------------------------

#[test]
fn saturated_home_shard_spills_to_least_loaded() {
    let gate = Gate::new(false);
    let (h0, j0) = spawn_shard(Some(gate.clone())); // home: blocked
    let (h1, j1) = spawn_shard(None); // spill target: open
    let mut router = Router::with_config(RouterConfig {
        policy: RoutePolicy::LeastLoaded,
        affinity: Affinity::Session,
        queue_depth: 1,
        overflow_depth: 4,
        default_deadline_ms: 0,
    });
    router.add_engine("shard0", h0.clone());
    router.add_engine("shard1", h1.clone());
    let home = session_for_shard(&router, 0, 2);

    // First request occupies the home shard (blocked in prefill ⇒ its
    // depth is pinned at 1 = queue_depth: saturated).
    let (_, rx_a) = router
        .submit_with(vec![1, 2, 3], 2, SamplingParams::default(), opts(&home))
        .unwrap();
    // Same session again: home saturated, spills to shard1 and finishes
    // even though the home shard is still stuck.
    let (_, rx_b) = router
        .submit_with(vec![1, 2, 3], 2, SamplingParams::default(), opts(&home))
        .unwrap();
    let (tokens, reason, ..) = collect_response(&rx_b);
    assert!(matches!(reason, FinishReason::Length), "{reason:?}");
    assert_eq!(tokens.len(), 2);
    assert_eq!(router.stats().spillovers, 1);
    assert_eq!(h1.metrics.snapshot().requests_submitted, 1);

    gate.open();
    let (_, reason, ..) = collect_response(&rx_a);
    assert!(matches!(reason, FinishReason::Length), "{reason:?}");
    h0.drain();
    h1.drain();
    j0.join().unwrap();
    j1.join().unwrap();
}

// ---------------------------------------------------------------------------
// Overflow queue: typed saturation, pump dispatch, no lost streams.
// ---------------------------------------------------------------------------

#[test]
fn full_queues_reject_typed_and_parked_requests_still_finish() {
    let gate = Gate::new(false);
    let (h0, j0) = spawn_shard(Some(gate.clone()));
    let (h1, j1) = spawn_shard(Some(gate.clone()));
    let mut router = Router::with_config(RouterConfig {
        policy: RoutePolicy::LeastLoaded,
        affinity: Affinity::Session,
        queue_depth: 1,
        overflow_depth: 1,
        default_deadline_ms: 0,
    });
    router.add_engine("shard0", h0.clone());
    router.add_engine("shard1", h1.clone());
    let router = Arc::new(router);
    let pump = router.spawn_pump();
    let home = session_for_shard(&router, 0, 2);

    // A occupies the home shard; B spills to the other; C parks in the
    // overflow queue; D finds every queue full and fails *typed* —
    // immediately, no hang.
    let submit = |r: &Router| {
        r.submit_with(vec![1, 2, 3], 2, SamplingParams::default(), opts(&home))
    };
    let (_, rx_a) = submit(&router).unwrap();
    let (_, rx_b) = submit(&router).unwrap();
    let (_, rx_c) = submit(&router).unwrap();
    match submit(&router) {
        Err(SubmitError::Saturated { retry_after_ms }) => assert!(retry_after_ms > 0),
        other => panic!("expected Saturated, got {other:?}"),
    }
    let stats = router.stats();
    assert_eq!(stats.spillovers, 1);
    assert_eq!(stats.overflow_enqueued, 1);
    assert_eq!(stats.rejected_saturated, 1);

    // Unblock the shards: A and B finish, freeing capacity; the pump
    // dispatches parked C, whose stream then finishes too — a parked
    // stream is never dropped.
    gate.open();
    for rx in [&rx_a, &rx_b, &rx_c] {
        let (tokens, reason, ..) = collect_response(rx);
        assert!(matches!(reason, FinishReason::Length), "{reason:?}");
        assert_eq!(tokens.len(), 2);
    }
    assert_eq!(router.stats().overflow_dispatched, 1);
    assert_eq!(router.stats().overflow_len, 0);

    router.stop_pump();
    pump.join().unwrap();
    h0.drain();
    h1.drain();
    j0.join().unwrap();
    j1.join().unwrap();
}

#[test]
fn pump_shutdown_rejects_parked_streams_instead_of_leaking() {
    let gate = Gate::new(false);
    let (h0, j0) = spawn_shard(Some(gate.clone()));
    let mut router = Router::with_config(RouterConfig {
        policy: RoutePolicy::LeastLoaded,
        affinity: Affinity::Session,
        queue_depth: 1,
        overflow_depth: 4,
        default_deadline_ms: 0,
    });
    router.add_engine("shard0", h0.clone());
    let router = Arc::new(router);
    let pump = router.spawn_pump();

    let (_, rx_a) = router
        .submit_with(vec![1, 2, 3], 2, SamplingParams::default(), opts("s"))
        .unwrap();
    // Single shard saturated and nowhere to spill: B parks.
    let (_, rx_b) = router
        .submit_with(vec![1, 2, 3], 2, SamplingParams::default(), opts("s"))
        .unwrap();
    assert_eq!(router.stats().overflow_enqueued, 1);

    // Shut the pump down while B is parked (the shard is still gated, so
    // the pump cannot have dispatched it): B's stream terminates with a
    // typed rejection rather than hanging the client.
    router.stop_pump();
    pump.join().unwrap();
    let (_, reason, ..) = collect_response(&rx_b);
    assert!(matches!(reason, FinishReason::Rejected(_)), "{reason:?}");

    gate.open();
    let (_, reason, ..) = collect_response(&rx_a);
    assert!(matches!(reason, FinishReason::Length), "{reason:?}");
    h0.drain();
    j0.join().unwrap();
}

// ---------------------------------------------------------------------------
// Determinism: 1 shard vs N shards, byte-identical.
// ---------------------------------------------------------------------------

/// Run an affinity-pinned trace on `shards` identical engines and return
/// every stream's tokens in submission order.
fn run_trace(trace: &Trace, shards: usize) -> Vec<Vec<i32>> {
    let mut router = Router::with_config(RouterConfig {
        policy: RoutePolicy::LeastLoaded,
        affinity: Affinity::Session,
        queue_depth: 0, // pure affinity placement, no load dependence
        overflow_depth: 4,
        default_deadline_ms: 0,
    });
    let mut handles = Vec::new();
    let mut joins = Vec::new();
    for i in 0..shards {
        let (h, j) = spawn_shard(None);
        router.add_engine(&format!("shard{i}"), h.clone());
        handles.push(h);
        joins.push(j);
    }
    let streams: Vec<_> = trace
        .requests
        .iter()
        .enumerate()
        .map(|(i, tr)| {
            // Mix greedy and seeded stochastic sampling: determinism must
            // hold for both, because the per-request RNG is derived from
            // (engine seed, prompt, sampling seed) — never from shard
            // state or arrival order.
            let sampling = SamplingParams {
                temperature: if i % 2 == 0 { 0.0 } else { 0.9 },
                top_k: 8,
                seed: tr.seed,
            };
            let (_, rx) = router
                .submit_with(tr.prompt.clone(), tr.max_new_tokens, sampling, opts(&tr.session))
                .unwrap();
            rx
        })
        .collect();
    let tokens = streams.iter().map(|rx| collect_response(rx).0).collect();
    for h in &handles {
        h.drain();
    }
    for j in joins {
        j.join().unwrap();
    }
    tokens
}

#[test]
fn affinity_pinned_trace_is_byte_identical_on_one_and_many_shards() {
    let trace = Trace::generate(&TraceConfig {
        requests: 12,
        arrivals: Arrivals::Poisson { rate: 1000.0 },
        prompt_len: LengthDist::Pareto { lo: 4, hi: 20, alpha: 1.3 },
        output_len: LengthDist::Uniform(2, 6),
        sessions: 4,
        vocab: 64,
        seed: 0xD17,
        ..Default::default()
    });
    let one = run_trace(&trace, 1);
    let three = run_trace(&trace, 3);
    assert!(one.iter().all(|t| !t.is_empty()));
    assert_eq!(one, three, "sharding changed generated bytes");
}
