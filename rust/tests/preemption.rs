//! Fork/COW through the *serving* path: optimistic admission, preemption
//! with recompute-on-readmission, and cross-request prefix sharing must
//! never change generated tokens — an overloaded pool only changes *when*
//! work runs, not *what* it computes.
//!
//! Uses the CPU oracle backend (test-tiny: layers=2, heads=2, block=8,
//! max_seq=32, vocab=64), so every step is deterministic and byte-exact
//! comparisons are meaningful.

use kvq::coordinator::admission::{AdmissionConfig, AdmissionMode};
use kvq::coordinator::batcher::BatcherConfig;
use kvq::coordinator::engine::{self, EngineConfig};
use kvq::coordinator::request::{collect_response, FinishReason};
use kvq::coordinator::router::{RoutePolicy, Router};
use kvq::coordinator::{EngineHandle, MetricsSnapshot};
use kvq::kvcache::{PolicySpec, Precision};
use kvq::model::runner::CpuBackend;
use kvq::model::sample::SamplingParams;
use kvq::model::weights::Weights;
use kvq::model::ModelSpec;

fn cpu_factory() -> impl FnOnce() -> anyhow::Result<Box<dyn kvq::model::LmBackend>> + Send {
    || {
        let spec = ModelSpec::test_tiny();
        let w = Weights::synthetic(&spec, 7);
        Ok(Box::new(CpuBackend::new(spec, w)) as Box<dyn kvq::model::LmBackend>)
    }
}

/// True when the CI cache-off job forces the prefix cache disabled
/// (`KVQ_PREFIX_CACHE_BLOCKS=0`): byte-identity assertions still hold,
/// the hit/saved-token expectations are skipped.
fn prefix_forced_off() -> bool {
    std::env::var("KVQ_PREFIX_CACHE_BLOCKS").as_deref() == Ok("0")
}

/// Engine with an explicit pool size / admission mode / prefix budget.
fn engine_with(
    num_blocks: Option<usize>,
    mode: AdmissionMode,
    prefix_cache_blocks: usize,
    max_prefills: usize,
) -> (EngineHandle, std::thread::JoinHandle<()>) {
    let cfg = EngineConfig {
        quant_policy: PolicySpec::uniform(Precision::Int8),
        num_blocks,
        prefix_cache_blocks,
        batcher: BatcherConfig {
            max_prefills_per_step: max_prefills,
            admission: AdmissionConfig { mode, max_running: 8, ..Default::default() },
            ..Default::default()
        },
        ..Default::default()
    };
    engine::spawn(cfg, cpu_factory())
}

/// Distinct, vocab-safe prompts (test-tiny vocab = 64).
fn prompts() -> Vec<Vec<i32>> {
    (0..6u8)
        .map(|i| {
            let len = if i == 1 { 10 } else { 8 }; // one unaligned prompt (COW tail)
            (0..len).map(|j| ((i as i32 + 2) * 7 + j as i32) % 64).collect()
        })
        .collect()
}

/// Run every prompt through an engine, one at a time (uncontended), and
/// return the token streams.
fn run_requests(
    h: &EngineHandle,
    prompts: &[Vec<i32>],
    max_new: usize,
    concurrent: bool,
) -> Vec<Vec<i32>> {
    let mut router = Router::new(RoutePolicy::RoundRobin);
    router.add_engine("e", h.clone());
    if concurrent {
        let streams: Vec<_> = prompts
            .iter()
            .map(|p| router.submit(p.clone(), max_new, SamplingParams::default()).unwrap().1)
            .collect();
        streams
            .iter()
            .map(|rx| {
                let (tokens, reason, ..) = collect_response(rx);
                assert_eq!(reason, FinishReason::Length, "request must finish");
                tokens
            })
            .collect()
    } else {
        prompts
            .iter()
            .map(|p| {
                let (_, rx) =
                    router.submit(p.clone(), max_new, SamplingParams::default()).unwrap();
                let (tokens, reason, ..) = collect_response(&rx);
                assert_eq!(reason, FinishReason::Length);
                tokens
            })
            .collect()
    }
}

fn drain(h: EngineHandle, join: std::thread::JoinHandle<()>) -> MetricsSnapshot {
    h.drain();
    join.join().unwrap();
    h.metrics.snapshot()
}

/// Uncontended reference outputs: huge pool, sequential submission.
fn baseline(max_new: usize) -> Vec<Vec<i32>> {
    let (h, join) = engine_with(None, AdmissionMode::Optimistic, 0, 1);
    let out = run_requests(&h, &prompts(), max_new, false);
    let m = drain(h, join);
    assert_eq!(m.preemptions, 0, "baseline must be uncontended");
    out
}

#[test]
fn overload_preempts_then_finishes_bit_identical() {
    let max_new = 16;
    let expect = baseline(max_new);

    // Pool of 24 blocks: each request's worst case is 12–16 blocks, so
    // six concurrent requests overload the pool ~3x. Optimistic admission
    // lets them in on prompt footprints; decode growth must then preempt.
    let (h, join) = engine_with(Some(24), AdmissionMode::Optimistic, 0, 6);
    let got = run_requests(&h, &prompts(), max_new, true);
    let m = drain(h, join);

    assert_eq!(got, expect, "preempted runs must be byte-identical to uncontended runs");
    assert_eq!(m.requests_finished, 6);
    assert!(m.preemptions > 0, "overload must actually preempt (got {})", m.preemptions);
    assert_eq!(m.resumes, m.preemptions, "every victim is readmitted exactly once");
    assert!(m.recompute_tokens > 0, "readmission recomputes prompt + trail");
    assert_eq!(m.preempted, 0, "nothing left parked after drain");
    assert_eq!(m.pool_total_blocks, 24);
}

#[test]
fn optimistic_sustains_more_concurrency_than_worst_case() {
    let max_new = 16;
    let expect = baseline(max_new);

    let run_mode = |mode: AdmissionMode| {
        let (h, join) = engine_with(Some(24), mode, 0, 6);
        let got = run_requests(&h, &prompts(), max_new, true);
        (got, drain(h, join))
    };
    let (got_wc, m_wc) = run_mode(AdmissionMode::WorstCase);
    let (got_opt, m_opt) = run_mode(AdmissionMode::Optimistic);

    assert_eq!(got_wc, expect, "worst-case admission changes nothing about outputs");
    assert_eq!(got_opt, expect, "optimistic admission changes nothing about outputs");
    assert_eq!(m_wc.preemptions, 0, "full reservation never needs preemption");
    assert!(
        m_opt.running_peak > m_wc.running_peak,
        "optimistic admission must sustain strictly more concurrent sequences \
         ({} vs {})",
        m_opt.running_peak,
        m_wc.running_peak
    );
}

#[test]
fn shared_prompt_prefix_is_bit_identical_and_hits() {
    let max_new = 8;
    let prompt: Vec<i32> = (0..8).map(|j| (j * 5 + 3) % 64).collect();
    let workload = vec![prompt.clone(), prompt.clone(), prompt];

    // Unshared reference: prefix cache disabled.
    let (h, join) = engine_with(None, AdmissionMode::Optimistic, 0, 1);
    let expect = run_requests(&h, &workload, max_new, false);
    let m = drain(h, join);
    assert_eq!(m.prefix_lookups, 0, "disabled cache never counts lookups");

    // Shared: second and third submissions fork the cached prompt blocks.
    let (h, join) = engine_with(None, AdmissionMode::Optimistic, 64, 1);
    let got = run_requests(&h, &workload, max_new, false);
    let m = drain(h, join);
    assert_eq!(got, expect, "prefix-shared runs must be byte-identical to unshared runs");
    if !prefix_forced_off() {
        assert_eq!(m.prefix_lookups, 3);
        assert!(m.prefix_hits >= 2, "repeat prompts must hit (got {})", m.prefix_hits);
        assert!(m.prefix_hit_rate() > 0.0);
        assert!(m.prefix_cache_blocks > 0, "entries stay pinned while budget allows");
    }
}

#[test]
fn partial_prefix_reuse_is_bit_identical_and_saves_prefill() {
    // Trie partial hits: three prompts share a two-block (16-token)
    // system prefix but diverge after it (one with a block-misaligned
    // tail), plus one exact repeat. The shared span must be served from
    // forked cache blocks (zero backend compute for it) without changing
    // a single generated token vs. the cache-disabled run.
    let max_new = 6;
    let sys: Vec<i32> = (0..16).map(|j| (j * 3 + 5) % 64).collect();
    let with_suffix = |i: i32, len: i32| -> Vec<i32> {
        let mut p = sys.clone();
        p.extend((0..len).map(|j| ((i + 2) * 11 + j) % 64));
        p
    };
    let a = with_suffix(0, 8); // block-aligned suffix
    let b = with_suffix(1, 8); // same shape, different tokens
    let c = with_suffix(2, 5); // misaligned tail
    let workload = vec![a.clone(), b, c, a];

    // Unshared reference: prefix cache disabled.
    let (h, join) = engine_with(None, AdmissionMode::Optimistic, 0, 1);
    let expect = run_requests(&h, &workload, max_new, false);
    let m = drain(h, join);
    assert_eq!(m.prefix_saved_tokens, 0, "disabled cache saves nothing");

    // Shared: miss, two partial hits (16 tokens each), one full hit.
    let (h, join) = engine_with(None, AdmissionMode::Optimistic, 64, 1);
    let got = run_requests(&h, &workload, max_new, false);
    let m = drain(h, join);
    assert_eq!(got, expect, "partial-prefix runs must be byte-identical to unshared runs");
    if !prefix_forced_off() {
        assert_eq!(m.prefix_lookups, 4);
        assert_eq!(m.prefix_hits, 1, "exact repeat is a full hit");
        assert_eq!(m.prefix_partial_hits, 2, "shared system prefix must partially hit");
        assert_eq!(
            m.prefix_saved_tokens,
            16 + 16 + 24,
            "two 2-block partial adoptions + one full 24-token hit"
        );
        assert!(m.prefix_trie_nodes > 0, "trie holds the shared chunks");
    }
}

#[test]
fn preemption_and_prefix_sharing_compose() {
    // 6 requests over 2 distinct prompts on an overloaded pool with a
    // prefix budget: hits, preemptions, and recompute all interleave and
    // the outputs still match the uncontended baseline exactly.
    let max_new = 16;
    let two: Vec<Vec<i32>> = vec![prompts()[0].clone(), prompts()[2].clone()];
    let workload: Vec<Vec<i32>> =
        (0..6).map(|i| two[i % 2].clone()).collect();

    let (h, join) = engine_with(None, AdmissionMode::Optimistic, 0, 1);
    let expect = run_requests(&h, &workload, max_new, false);
    drain(h, join);

    let (h, join) = engine_with(Some(24), AdmissionMode::Optimistic, 8, 6);
    let got = run_requests(&h, &workload, max_new, true);
    let m = drain(h, join);
    assert_eq!(got, expect, "sharing + preemption must not change outputs");
    assert_eq!(m.requests_finished, 6);
    if !prefix_forced_off() {
        assert!(m.prefix_hits > 0, "repeated prompts should hit (got {})", m.prefix_hits);
    }
    assert!(m.preemptions > 0, "pool is 3x oversubscribed (got {})", m.preemptions);
}

#[test]
fn preempted_requests_survive_queue_and_stream_tokens_incrementally() {
    // A preempted request's client stream stays live across the park /
    // readmit cycle: it sees First + every Token + Finished, in order.
    let max_new = 12;
    let (h, join) = engine_with(Some(16), AdmissionMode::Optimistic, 0, 4);
    let mut router = Router::new(RoutePolicy::RoundRobin);
    router.add_engine("e", h.clone());
    let streams: Vec<_> = prompts()[..4]
        .iter()
        .map(|p| router.submit(p.clone(), max_new, SamplingParams::default()).unwrap().1)
        .collect();
    for rx in &streams {
        let (tokens, reason, ttft, elapsed) = collect_response(rx);
        assert_eq!(reason, FinishReason::Length);
        assert_eq!(tokens.len(), max_new);
        assert!(ttft > 0.0 && elapsed >= ttft);
    }
    let m = drain(h, join);
    assert_eq!(m.requests_finished, 4);
    assert!(m.preemptions > 0, "16-block pool must preempt 4 growing sequences");
}
