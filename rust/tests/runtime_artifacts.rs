//! Integration: PJRT runtime × AOT artifacts × Rust CPU oracle.
//!
//! Requires `make artifacts` (skips with a note if absent). Every kernel
//! artifact must agree with the pure-Rust quantizer — the same contract
//! the Python suite enforces against the jnp oracle, now across the
//! language boundary.

use kvq::quant::{self, Fp32Matrix, Int8Matrix};
use kvq::runtime::{HostTensor, Runtime};

fn runtime() -> Option<Runtime> {
    let dir = kvq::runtime::default_artifact_dir();
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {dir}; run `make artifacts`");
        return None;
    }
    Some(Runtime::new(&dir).expect("runtime"))
}

const T: usize = 2048;
const D: usize = 128;
const TAG: &str = "2048x128";

fn sample() -> (Fp32Matrix, Vec<f32>) {
    let k = Fp32Matrix::random_uniform(T, D, -1.0, 1.0, 0xBEEF);
    let s = quant::compute_scales(&k);
    (k, s)
}

#[test]
fn scales_artifact_matches_cpu() {
    let Some(rt) = runtime() else { return };
    let (k, s) = sample();
    let out = rt
        .run(&format!("scales_{TAG}"), &[HostTensor::f32(k.data.clone(), &[T, D])])
        .unwrap();
    let got = out[0].as_f32().unwrap();
    assert_eq!(got.len(), D);
    for (a, b) in got.iter().zip(&s) {
        assert!((a - b).abs() <= 1e-6 * b.abs().max(1e-6), "{a} vs {b}");
    }
}

#[test]
fn quantize_artifacts_match_cpu_all_variants() {
    let Some(rt) = runtime() else { return };
    let (k, s) = sample();
    let mut cpu = Int8Matrix::zeros(T, D);
    quant::quantize::quantize_naive(&k, &s, &mut cpu);
    for variant in ["naive", "tiled", "coarsened", "vectorized"] {
        let out = rt
            .run(
                &format!("quantize_{variant}_{TAG}"),
                &[HostTensor::f32(k.data.clone(), &[T, D]), HostTensor::f32(s.clone(), &[D])],
            )
            .unwrap();
        let got = out[0].as_i8().unwrap();
        assert_eq!(got, cpu.data.as_slice(), "variant {variant} diverged from CPU");
    }
}

#[test]
fn dequantize_artifact_matches_cpu() {
    let Some(rt) = runtime() else { return };
    let (k, s) = sample();
    let mut q = Int8Matrix::zeros(T, D);
    quant::quantize::quantize_vectorized(&k, &s, &mut q);
    let cpu = quant::dequantize(&q);
    let out = rt
        .run(
            &format!("dequantize_vectorized_{TAG}"),
            &[HostTensor::i8(q.data.clone(), &[T, D]), HostTensor::f32(s.clone(), &[D])],
        )
        .unwrap();
    let got = out[0].as_f32().unwrap();
    for (a, b) in got.iter().zip(&cpu.data) {
        assert!((a - b).abs() <= 1e-6, "{a} vs {b}");
    }
}

#[test]
fn fused_artifact_matches_cpu_within_ulp() {
    let Some(rt) = runtime() else { return };
    let (k, _) = sample();
    let cpu = quant::quantize_fused(&k);
    let out = rt
        .run(&format!("quantize_fused_{TAG}"), &[HostTensor::f32(k.data.clone(), &[T, D])])
        .unwrap();
    let got_q = out[0].as_i8().unwrap();
    let got_s = out[1].as_f32().unwrap();
    for (a, b) in got_s.iter().zip(&cpu.scales) {
        assert!((a - b).abs() <= 1e-6 * b.abs().max(1e-9), "scale {a} vs {b}");
    }
    // XLA may fold /127 into *(1/127): allow ±1 on rounding boundaries.
    let mismatches = got_q
        .iter()
        .zip(&cpu.data)
        .filter(|(a, b)| a != b)
        .inspect(|(a, b)| assert!((**a as i32 - **b as i32).abs() <= 1, "{a} vs {b}"))
        .count();
    assert!(mismatches as f64 / cpu.data.len() as f64 <= 0.01, "{mismatches} mismatches");
}

#[test]
fn quantize_ref_artifact_agrees() {
    let Some(rt) = runtime() else { return };
    let (k, _) = sample();
    let cpu = quant::quantize_fused(&k);
    let out = rt
        .run(&format!("quantize_ref_{TAG}"), &[HostTensor::f32(k.data.clone(), &[T, D])])
        .unwrap();
    let got_q = out[0].as_i8().unwrap();
    let diff = got_q.iter().zip(&cpu.data).filter(|(a, b)| a != b).count();
    assert!(diff as f64 / cpu.data.len() as f64 <= 0.01, "{diff} mismatches");
}

#[test]
fn attnerr_artifact_matches_cpu_metric() {
    let Some(rt) = runtime() else { return };
    let (k, s) = sample();
    let mut q = Int8Matrix::zeros(T, D);
    quant::quantize::quantize_vectorized(&k, &s, &mut q);
    let k_hat = quant::dequantize(&q);
    let nq = 64;
    let queries = Fp32Matrix::random_uniform(nq, D, -1.0, 1.0, 77);
    let cpu = quant::attention_score_error(&queries, &k, &k_hat);
    let out = rt
        .run(
            &format!("attnerr_{TAG}"),
            &[
                HostTensor::f32(queries.data.clone(), &[nq, D]),
                HostTensor::f32(k.data.clone(), &[T, D]),
                HostTensor::i8(q.data.clone(), &[T, D]),
                HostTensor::f32(s.clone(), &[D]),
            ],
        )
        .unwrap();
    let got = out[0].as_f32().unwrap()[0] as f64;
    assert!((got - cpu).abs() <= 1e-4 * cpu.max(1e-9), "{got} vs {cpu}");
}

#[test]
fn input_validation_rejects_bad_shapes() {
    let Some(rt) = runtime() else { return };
    let err = rt
        .run(&format!("scales_{TAG}"), &[HostTensor::f32(vec![0.0; 4], &[2, 2])])
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("shape"), "unexpected error: {msg}");
    let err = rt
        .run(
            &format!("quantize_naive_{TAG}"),
            &[HostTensor::i8(vec![0; T * D], &[T, D]), HostTensor::f32(vec![0.0; D], &[D])],
        )
        .unwrap_err();
    assert!(format!("{err:#}").contains("dtype"));
}

#[test]
fn executable_cache_reuses_compilations() {
    let Some(rt) = runtime() else { return };
    let name = format!("scales_{TAG}");
    let a = rt.load(&name).unwrap();
    let n = rt.compiled_count();
    let b = rt.load(&name).unwrap();
    assert_eq!(rt.compiled_count(), n);
    assert!(std::rc::Rc::ptr_eq(&a, &b));
}
