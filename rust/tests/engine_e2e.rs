//! Cross-language model parity + full-stack generation.
//!
//! The strongest correctness signal in the repo: the pure-Rust transformer
//! oracle (cpu_ref) and the jax-authored, AOT-compiled artifacts must
//! produce matching logits for the same synthetic weights, through prefill
//! AND through INT8-cache decode — proving L1 (Pallas kernels), L2 (jax
//! graph), and L3 (Rust cache manager + runtime) implement the same model.

use kvq::kvcache::manager::{CacheConfig, KvCacheManager};
use kvq::kvcache::{Precision, QuantPolicy};
use kvq::model::runner::{CpuBackend, DecodeKernel};
use kvq::model::weights::Weights;
use kvq::model::{LmBackend, PjrtBackend};
use kvq::runtime::Runtime;
use std::rc::Rc;

const SEED: u64 = 0xA11CE;

fn runtime() -> Option<Rc<Runtime>> {
    let dir = kvq::runtime::default_artifact_dir();
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {dir}; run `make artifacts`");
        return None;
    }
    Some(Rc::new(Runtime::new(&dir).expect("runtime")))
}

fn backends(rt: &Rc<Runtime>, kernel: DecodeKernel) -> (PjrtBackend, CpuBackend) {
    let pjrt = PjrtBackend::new(rt.clone(), "kvq-3m", SEED, kernel).expect("pjrt backend");
    let spec = pjrt.spec().clone();
    let cpu = CpuBackend::new(spec.clone(), Weights::synthetic(&spec, SEED));
    (pjrt, cpu)
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

fn argmax(v: &[f32]) -> usize {
    v.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0
}

#[test]
fn prefill_logits_match_cpu_oracle() {
    let Some(rt) = runtime() else { return };
    let (pjrt, cpu) = backends(&rt, DecodeKernel::PlainXla);
    let tokens: Vec<i32> = "the quick brown fox".bytes().map(|b| b as i32).collect();
    let a = pjrt.prefill(&tokens, tokens.len()).unwrap();
    let b = cpu.prefill(&tokens, tokens.len()).unwrap();
    let d = max_abs_diff(&a.logits, &b.logits);
    assert!(d < 5e-3, "prefill logits diverge: {d}");
    assert_eq!(argmax(&a.logits), argmax(&b.logits));
    // Caches agree too (valid rows). The PJRT backend may return a
    // bucketed stride (S < max_seq); the CPU oracle always uses max_seq.
    let spec = pjrt.spec();
    let (l, h, dd) = (spec.layers, spec.heads, spec.head_dim);
    let sa = a.k.len() / (l * h * dd);
    let sb = b.k.len() / (l * h * dd);
    for li in 0..l {
        for hi in 0..h {
            for t in 0..tokens.len() {
                let ba = ((li * h + hi) * sa + t) * dd;
                let bb = ((li * h + hi) * sb + t) * dd;
                let dk = max_abs_diff(&a.k[ba..ba + dd], &b.k[bb..bb + dd]);
                assert!(dk < 1e-3, "K cache diverges at l{li} h{hi} t{t}: {dk}");
            }
        }
    }
}

#[test]
fn int8_decode_matches_cpu_oracle() {
    let Some(rt) = runtime() else { return };
    let (pjrt, cpu) = backends(&rt, DecodeKernel::PlainXla);
    let spec = pjrt.spec().clone();
    let tokens: Vec<i32> = (0..9).map(|i| (i * 31 + 7) % 256).collect();
    let n = 8;

    // Prefill via the artifact, quantize into the paged cache manager.
    let pre = pjrt.prefill(&tokens[..n], n).unwrap();
    let cfg = CacheConfig {
        layers: spec.layers,
        heads: spec.heads,
        head_dim: spec.head_dim,
        max_seq: spec.max_seq,
        block_size: spec.block_size,
        num_blocks: 4096,
        scale_margin: 1.0,
    };
    let mut mgr =
        KvCacheManager::new(cfg, QuantPolicy::uniform(Precision::Int8, cfg.layers, cfg.heads));
    let id = mgr.new_sequence();
    mgr.set_prefill(id, &pre.k, &pre.v, n).unwrap();

    // Gather staging exactly as the engine does.
    let (l, h, s, d) = (spec.layers, spec.heads, spec.max_seq, spec.head_dim);
    let mut kq = vec![0i8; l * h * s * d];
    let mut vq = vec![0i8; l * h * s * d];
    let mut ks = vec![0f32; l * h * d];
    let mut vs = vec![0f32; l * h * d];
    for li in 0..l {
        mgr.gather_i8(id, li, 0, &mut kq[li * h * s * d..(li + 1) * h * s * d]).unwrap();
        mgr.gather_i8(id, li, 1, &mut vq[li * h * s * d..(li + 1) * h * s * d]).unwrap();
        ks[li * h * d..(li + 1) * h * d].copy_from_slice(mgr.scales(id, li, 0).unwrap());
        vs[li * h * d..(li + 1) * h * d].copy_from_slice(mgr.scales(id, li, 1).unwrap());
    }

    let isa = kvq::quant::simd::default_isa();
    let a = pjrt.decode_i8(tokens[n], n, &kq, &ks, &vq, &vs, isa).unwrap();
    let b = cpu.decode_i8(tokens[n], n, &kq, &ks, &vq, &vs, isa).unwrap();
    let dl = max_abs_diff(&a.logits, &b.logits);
    assert!(dl < 5e-3, "decode logits diverge: {dl}");
    assert_eq!(argmax(&a.logits), argmax(&b.logits));
    let dk = max_abs_diff(&a.k_new, &b.k_new);
    assert!(dk < 1e-3, "k_new diverges: {dk}");
}

#[test]
fn pallas_decode_matches_plain_xla_decode() {
    let Some(rt) = runtime() else { return };
    let (plain, _) = backends(&rt, DecodeKernel::PlainXla);
    let pallas = PjrtBackend::new(rt.clone(), "kvq-3m", SEED, DecodeKernel::Pallas).unwrap();
    let spec = plain.spec().clone();
    let tokens: Vec<i32> = (0..6).map(|i| (i * 17 + 3) % 256).collect();
    let n = 5;
    let pre = plain.prefill(&tokens[..n], n).unwrap();

    // Quantize per-(layer,head) on host (engine-equivalent, simple form).
    // The prefill output may use a bucketed stride s_src < max_seq; the
    // decode artifact expects max_seq-strided caches.
    let (l, h, s, d) = (spec.layers, spec.heads, spec.max_seq, spec.head_dim);
    let s_src = pre.k.len() / (l * h * d);
    let mut kq = vec![0i8; l * h * s * d];
    let mut vq = vec![0i8; l * h * s * d];
    let mut ks = vec![0f32; l * h * d];
    let mut vs = vec![0f32; l * h * d];
    for (src, dst_q, dst_s) in
        [(&pre.k, &mut kq, &mut ks), (&pre.v, &mut vq, &mut vs)]
    {
        for li in 0..l {
            for hi in 0..h {
                for ch in 0..d {
                    let mut m = 0.0f32;
                    for t in 0..n {
                        m = m.max(src[((li * h + hi) * s_src + t) * d + ch].abs());
                    }
                    dst_s[(li * h + hi) * d + ch] = m / 127.0;
                }
                for t in 0..n {
                    for ch in 0..d {
                        let i_src = ((li * h + hi) * s_src + t) * d + ch;
                        let i_dst = ((li * h + hi) * s + t) * d + ch;
                        dst_q[i_dst] = kvq::quant::quantize::quantize_one(
                            src[i_src],
                            dst_s[(li * h + hi) * d + ch],
                        );
                    }
                }
            }
        }
    }

    let isa = kvq::quant::simd::default_isa();
    let a = plain.decode_i8(tokens[n], n, &kq, &ks, &vq, &vs, isa).unwrap();
    let b = pallas.decode_i8(tokens[n], n, &kq, &ks, &vq, &vs, isa).unwrap();
    let dl = max_abs_diff(&a.logits, &b.logits);
    assert!(dl < 1e-3, "pallas vs plain decode: {dl}");
}

#[test]
fn fp32_decode_baseline_matches_cpu() {
    let Some(rt) = runtime() else { return };
    let (pjrt, cpu) = backends(&rt, DecodeKernel::PlainXla);
    let tokens: Vec<i32> = (0..7).map(|i| (i * 13 + 1) % 256).collect();
    let n = 6;
    let pre = pjrt.prefill(&tokens[..n], n).unwrap();
    // Re-stride the bucketed prefill cache to the decode artifact's
    // (L, H, max_seq, d) layout.
    let spec = pjrt.spec().clone();
    let (l, h, s, d) = (spec.layers, spec.heads, spec.max_seq, spec.head_dim);
    let s_src = pre.k.len() / (l * h * d);
    let mut k = vec![0f32; l * h * s * d];
    let mut v = vec![0f32; l * h * s * d];
    for lh in 0..l * h {
        for t in 0..n {
            let src = (lh * s_src + t) * d;
            let dst = (lh * s + t) * d;
            k[dst..dst + d].copy_from_slice(&pre.k[src..src + d]);
            v[dst..dst + d].copy_from_slice(&pre.v[src..src + d]);
        }
    }
    let isa = kvq::quant::simd::default_isa();
    let a = pjrt.decode_f32(tokens[n], n, &k, &v, isa).unwrap();
    let b = cpu.decode_f32(tokens[n], n, &k, &v, isa).unwrap();
    let dl = max_abs_diff(&a.logits, &b.logits);
    assert!(dl < 5e-3, "fp32 decode diverges: {dl}");
}

#[test]
fn greedy_generation_trajectories_agree() {
    // Multi-step: generate 6 tokens with both backends through the real
    // cache manager; trajectories must be identical (greedy).
    let Some(rt) = runtime() else { return };
    let (pjrt, cpu) = backends(&rt, DecodeKernel::PlainXla);
    let spec = pjrt.spec().clone();

    let gen = |backend: &dyn LmBackend| -> Vec<i32> {
        let prompt: Vec<i32> = "kv".bytes().map(|b| b as i32).collect();
        let cfg = CacheConfig {
            layers: spec.layers,
            heads: spec.heads,
            head_dim: spec.head_dim,
            max_seq: spec.max_seq,
            block_size: spec.block_size,
            num_blocks: 4096,
            scale_margin: 1.0,
        };
        let mut mgr = KvCacheManager::new(
            cfg,
            QuantPolicy::uniform(Precision::Int8, cfg.layers, cfg.heads),
        );
        let id = mgr.new_sequence();
        let pre = backend.prefill(&prompt, prompt.len()).unwrap();
        mgr.set_prefill(id, &pre.k, &pre.v, prompt.len()).unwrap();
        let mut out = Vec::new();
        let mut token = argmax(&pre.logits) as i32;
        out.push(token);
        let (l, h, s, d) = (spec.layers, spec.heads, spec.max_seq, spec.head_dim);
        let mut kq = vec![0i8; l * h * s * d];
        let mut vq = vec![0i8; l * h * s * d];
        let mut ks = vec![0f32; l * h * d];
        let mut vs = vec![0f32; l * h * d];
        for step in 0..5 {
            let pos = prompt.len() + step;
            for li in 0..l {
                mgr.gather_i8(id, li, 0, &mut kq[li * h * s * d..(li + 1) * h * s * d]).unwrap();
                mgr.gather_i8(id, li, 1, &mut vq[li * h * s * d..(li + 1) * h * s * d]).unwrap();
                ks[li * h * d..(li + 1) * h * d].copy_from_slice(mgr.scales(id, li, 0).unwrap());
                vs[li * h * d..(li + 1) * h * d].copy_from_slice(mgr.scales(id, li, 1).unwrap());
            }
            let dec = backend
                .decode_i8(token, pos, &kq, &ks, &vq, &vs, kvq::quant::simd::default_isa())
                .unwrap();
            mgr.append_row(id, &dec.k_new, &dec.v_new).unwrap();
            token = argmax(&dec.logits) as i32;
            out.push(token);
        }
        out
    };

    let a = gen(&pjrt);
    let b = gen(&cpu);
    assert_eq!(a, b, "greedy trajectories diverged: {a:?} vs {b:?}");
}
