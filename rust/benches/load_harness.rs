//! Trace-driven load harness for the sharded serving front door.
//!
//! Replays a seed-deterministic [`Trace`] (bursty or Poisson arrivals,
//! heavy-tailed prompt/output lengths, sessions, priority classes)
//! against N engine shards behind the affine router, in two client
//! models:
//!
//! - **open loop**: a dispatcher thread submits each request at its
//!   trace timestamp regardless of completions (arrival-driven — the
//!   model that actually exposes queueing delay under overload);
//! - **closed loop**: W workers submit the next request only when their
//!   previous one finishes (concurrency-driven).
//!
//! Latency is *client-observed*: TTFT runs from the submit call to the
//! `First` event (so router overflow queueing counts), inter-token
//! latency from each token event to the next. Reports p50/p99/p999 for
//! both, plus throughput, preemption/spillover/overflow counts, and
//! typed-rejection totals under overload.
//!
//! Flags: --smoke (CPU oracle, undersized pool, bursty overload trace,
//!                 ≥2 shards; the CI load-smoke job runs this and emits
//!                 BENCH_load_smoke.json)
//!        --mode open|closed|both (default both)
//!        --shards N --requests N --rate R --sessions N --workers N
//!        --queue-depth N --overflow-depth N --seed N
//!
//! Emits `bench_results/BENCH_load_smoke.json` (smoke) or
//! `BENCH_load.json` (full), schema kvq-bench-v1. Exits non-zero if any
//! request is dropped or stuck: every submission must reach a terminal
//! state (finished or typed-rejected) with zero transport errors.

use kvq::bench::workload::{Arrivals, LengthDist, Trace, TraceConfig, TraceRequest};
use kvq::bench::BenchReport;
use kvq::coordinator::admission::AdmissionConfig;
use kvq::coordinator::batcher::BatcherConfig;
use kvq::coordinator::engine::{self, EngineConfig};
use kvq::coordinator::request::{EventRx, FinishReason, TokenEvent};
use kvq::coordinator::router::{
    Affinity, RoutePolicy, Router, RouterConfig, SubmitError, SubmitOptions,
};
use kvq::kvcache::{PolicySpec, Precision};
use kvq::model::runner::CpuBackend;
use kvq::model::sample::SamplingParams;
use kvq::model::weights::Weights;
use kvq::model::ModelSpec;
use kvq::util::args::Args;
use kvq::util::json::Json;
use kvq::util::stats::Summary;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How one request's stream ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Terminal {
    /// Length/stop/capacity: the request ran and terminated normally.
    Finished,
    /// Typed admission rejection (`FinishReason::Rejected` → HTTP 429).
    Rejected,
    /// Typed saturation at submit (`SubmitError::Saturated` → HTTP 503).
    Saturated,
    /// Engine error or a dropped stream — always a harness failure.
    Error,
}

/// Client-side record for one request.
struct Outcome {
    terminal: Terminal,
    ttft_s: Option<f64>,
    /// Gaps between consecutive token events (inter-token latency).
    gaps: Vec<f64>,
    tokens: usize,
}

/// Drain one stream, timing events as the client sees them.
fn drive_stream(rx: &EventRx, submitted: Instant) -> Outcome {
    let mut out =
        Outcome { terminal: Terminal::Error, ttft_s: None, gaps: Vec::new(), tokens: 0 };
    let mut last = submitted;
    loop {
        match rx.recv() {
            Ok(TokenEvent::First { .. }) => {
                // Client-observed TTFT: includes router overflow queueing
                // and engine waiting time, not just prefill.
                out.ttft_s = Some(submitted.elapsed().as_secs_f64());
                last = Instant::now();
                out.tokens += 1;
            }
            Ok(TokenEvent::Token(_)) => {
                let now = Instant::now();
                out.gaps.push((now - last).as_secs_f64());
                last = now;
                out.tokens += 1;
            }
            Ok(TokenEvent::Finished { reason, .. }) => {
                out.terminal = match reason {
                    FinishReason::Rejected(_) => Terminal::Rejected,
                    FinishReason::Error(_) => Terminal::Error,
                    _ => Terminal::Finished,
                };
                return out;
            }
            // Sender dropped without a Finished event: a lost stream.
            Err(_) => return out,
        }
    }
}

fn submit_trace_req(
    router: &Router,
    tr: &TraceRequest,
) -> Result<EventRx, SubmitError> {
    let sampling = SamplingParams { temperature: 0.0, top_k: 0, seed: tr.seed };
    router
        .submit_with(
            tr.prompt.clone(),
            tr.max_new_tokens,
            sampling,
            SubmitOptions {
                session: Some(tr.session.clone()),
                priority: Some(tr.priority),
                ..Default::default()
            },
        )
        .map(|(_, rx)| rx)
}

/// Open loop: submit at trace timestamps, collect on per-request threads.
fn run_open(router: &Arc<Router>, trace: &Trace) -> Vec<Outcome> {
    let t0 = Instant::now();
    let mut joins = Vec::new();
    let mut outcomes = Vec::new();
    for tr in &trace.requests {
        let wait = tr.at_s - t0.elapsed().as_secs_f64();
        if wait > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(wait));
        }
        let submitted = Instant::now();
        match submit_trace_req(router, tr) {
            Ok(rx) => {
                joins.push(std::thread::spawn(move || drive_stream(&rx, submitted)))
            }
            Err(SubmitError::Saturated { .. }) => outcomes.push(Outcome {
                terminal: Terminal::Saturated,
                ttft_s: None,
                gaps: Vec::new(),
                tokens: 0,
            }),
            Err(e) => panic!("unexpected submit error in open loop: {e}"),
        }
    }
    for j in joins {
        outcomes.push(j.join().expect("collector thread panicked"));
    }
    outcomes
}

/// Closed loop: `workers` clients each submit-then-wait over a shared
/// trace cursor; a saturated submit backs off and retries (closed-loop
/// clients wait rather than walk away), bounded so the run cannot hang.
fn run_closed(router: &Arc<Router>, trace: &Trace, workers: usize) -> Vec<Outcome> {
    let cursor = Arc::new(AtomicUsize::new(0));
    let trace = Arc::new(trace.clone());
    let joins: Vec<_> = (0..workers.max(1))
        .map(|_| {
            let router = Arc::clone(router);
            let cursor = Arc::clone(&cursor);
            let trace = Arc::clone(&trace);
            std::thread::spawn(move || {
                let mut outcomes = Vec::new();
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= trace.requests.len() {
                        return outcomes;
                    }
                    let tr = &trace.requests[i];
                    let mut attempts = 0;
                    let outcome = loop {
                        let submitted = Instant::now();
                        match submit_trace_req(&router, tr) {
                            Ok(rx) => break drive_stream(&rx, submitted),
                            Err(SubmitError::Saturated { retry_after_ms }) => {
                                attempts += 1;
                                if attempts >= 50 {
                                    break Outcome {
                                        terminal: Terminal::Saturated,
                                        ttft_s: None,
                                        gaps: Vec::new(),
                                        tokens: 0,
                                    };
                                }
                                std::thread::sleep(Duration::from_millis(
                                    retry_after_ms.min(20),
                                ));
                            }
                            Err(e) => panic!("unexpected submit error in closed loop: {e}"),
                        }
                    };
                    outcomes.push(outcome);
                }
            })
        })
        .collect();
    joins
        .into_iter()
        .flat_map(|j| j.join().expect("worker thread panicked"))
        .collect()
}

/// One shard fleet: engines + router + overflow pump.
struct Fleet {
    router: Arc<Router>,
    handles: Vec<kvq::coordinator::EngineHandle>,
    engine_joins: Vec<std::thread::JoinHandle<()>>,
    pump: std::thread::JoinHandle<()>,
}

fn spawn_fleet(shards: usize, queue_depth: usize, overflow_depth: usize) -> Fleet {
    // Deliberately undersized pool per shard (~2 worst-case sequences on
    // test-tiny) with a small running cap: the overload shape that forces
    // preemption inside shards and spillover/overflow between them.
    let spec = ModelSpec::test_tiny();
    let blocks_per_seq = 2 * spec.layers * spec.max_seq.div_ceil(spec.block_size);
    let num_blocks = blocks_per_seq * 2;
    let mut router = Router::with_config(RouterConfig {
        policy: RoutePolicy::LeastLoaded,
        affinity: Affinity::Session,
        queue_depth,
        overflow_depth,
        default_deadline_ms: 0,
    });
    let mut handles = Vec::new();
    let mut engine_joins = Vec::new();
    for i in 0..shards {
        let ecfg = EngineConfig {
            quant_policy: PolicySpec::uniform(Precision::Int8),
            num_blocks: Some(num_blocks),
            seed: 0xA11CE, // identical shards: placement never changes tokens
            batcher: BatcherConfig {
                max_prefills_per_step: 2,
                admission: AdmissionConfig { max_running: 4, ..Default::default() },
                ..Default::default()
            },
            ..Default::default()
        };
        let (h, join) = engine::spawn(ecfg, || {
            let spec = ModelSpec::test_tiny();
            let w = Weights::synthetic(&spec, 7);
            Ok(Box::new(CpuBackend::new(spec, w)) as Box<dyn kvq::model::LmBackend>)
        });
        router.add_engine(&format!("shard{i}"), h.clone());
        handles.push(h);
        engine_joins.push(join);
    }
    let router = Arc::new(router);
    let pump = router.spawn_pump();
    Fleet { router, handles, engine_joins, pump }
}

impl Fleet {
    /// Drain engines and stop the pump; returns when every thread exits.
    fn shutdown(self) {
        self.router.stop_pump();
        self.pump.join().expect("pump thread panicked");
        for h in &self.handles {
            h.drain();
        }
        for j in self.engine_joins {
            j.join().expect("engine thread panicked");
        }
    }
}

/// Aggregate one scenario's outcomes into the report; returns
/// (completed, rejected, saturated, errors).
#[allow(clippy::too_many_arguments)]
fn record_scenario(
    report: &mut BenchReport,
    label: &str,
    trace_len: usize,
    outcomes: &[Outcome],
    fleet: &Fleet,
    wall_s: f64,
    shards: usize,
) -> (usize, usize, usize, usize) {
    let mut ttft = Summary::new();
    let mut itl = Summary::new();
    let mut tokens = 0usize;
    let (mut completed, mut rejected, mut saturated, mut errors) = (0, 0, 0, 0);
    for o in outcomes {
        match o.terminal {
            Terminal::Finished => completed += 1,
            Terminal::Rejected => rejected += 1,
            Terminal::Saturated => saturated += 1,
            Terminal::Error => errors += 1,
        }
        if let Some(t) = o.ttft_s {
            ttft.add(t);
        }
        for &g in &o.gaps {
            itl.add(g);
        }
        tokens += o.tokens;
    }
    let stats = fleet.router.stats();
    let (mut preemptions, mut resumes) = (0u64, 0u64);
    for (_, h) in fleet.router.shards() {
        let snap = h.metrics.snapshot();
        preemptions += snap.preemptions;
        resumes += snap.resumes;
    }
    report.add(
        "load",
        label,
        None,
        &[
            ("requests", Json::Num(trace_len as f64)),
            ("shards", Json::Num(shards as f64)),
            ("wall_s", Json::Num(wall_s)),
            ("tok_per_s", Json::Num(tokens as f64 / wall_s.max(1e-9))),
            ("tokens", Json::Num(tokens as f64)),
            ("completed", Json::Num(completed as f64)),
            ("rejected_admission", Json::Num(rejected as f64)),
            ("rejected_saturated", Json::Num(saturated as f64)),
            ("errors", Json::Num(errors as f64)),
            ("ttft_p50_s", Json::Num(ttft.percentile(50.0))),
            ("ttft_p99_s", Json::Num(ttft.percentile(99.0))),
            ("ttft_p999_s", Json::Num(ttft.percentile(99.9))),
            ("itl_p50_s", Json::Num(itl.percentile(50.0))),
            ("itl_p99_s", Json::Num(itl.percentile(99.0))),
            ("itl_p999_s", Json::Num(itl.percentile(99.9))),
            ("preemptions", Json::Num(preemptions as f64)),
            ("resumes", Json::Num(resumes as f64)),
            ("spillovers", Json::Num(stats.spillovers as f64)),
            ("overflow_enqueued", Json::Num(stats.overflow_enqueued as f64)),
            ("overflow_dispatched", Json::Num(stats.overflow_dispatched as f64)),
            ("overflow_peak", Json::Num(stats.overflow_peak as f64)),
            ("router_rejected_saturated", Json::Num(stats.rejected_saturated as f64)),
        ],
    );
    println!(
        "[{label}] {completed} completed / {rejected} rejected(429) / {saturated} \
         saturated(503) / {errors} errors | ttft p50 {:.1}ms p99 {:.1}ms p999 {:.1}ms | \
         itl p50 {:.2}ms p99 {:.2}ms | {} spillovers, {} overflowed, {preemptions} preemptions",
        ttft.percentile(50.0) * 1e3,
        ttft.percentile(99.0) * 1e3,
        ttft.percentile(99.9) * 1e3,
        itl.percentile(50.0) * 1e3,
        itl.percentile(99.0) * 1e3,
        stats.spillovers,
        stats.overflow_enqueued,
    );
    (completed, rejected, saturated, errors)
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let smoke = args.has("smoke");
    let shards = args.usize_or("shards", 2).max(1);
    let requests = args.usize_or("requests", if smoke { 48 } else { 256 });
    let rate = args.f64_or("rate", 300.0);
    let sessions = args.usize_or("sessions", 6);
    let workers = args.usize_or("workers", 8);
    let queue_depth = args.usize_or("queue-depth", 6);
    let overflow_depth = args.usize_or("overflow-depth", 16);
    let seed = args.u64_or("seed", 0x10AD);
    let mode = args.str_or("mode", "both");

    // Heavy-tailed lengths bounded so the largest prompt plus the
    // largest output budget stays strictly inside the oracle model's
    // max_seq (the cache bails at the exact boundary).
    let spec = ModelSpec::test_tiny();
    let prompt_hi = spec.max_seq * 5 / 8;
    let out_hi = (spec.max_seq - prompt_hi) / 2;
    let tcfg = TraceConfig {
        requests,
        arrivals: Arrivals::Bursty { rate, on_s: 0.05, off_s: 0.05 },
        prompt_len: LengthDist::Pareto { lo: 4, hi: prompt_hi, alpha: 1.2 },
        output_len: LengthDist::Uniform(2, out_hi),
        sessions,
        vocab: spec.vocab,
        seed,
        ..Default::default()
    };
    let trace = Trace::generate(&tcfg);

    let mut report = BenchReport::new(if smoke { "load_smoke" } else { "load" });
    report.env("smoke", Json::Bool(smoke));
    report.env("shards", Json::Num(shards as f64));
    report.env("requests", Json::Num(requests as f64));
    report.env("rate_per_s", Json::Num(rate));
    report.env("queue_depth", Json::Num(queue_depth as f64));
    report.env("overflow_depth", Json::Num(overflow_depth as f64));
    report.env("seed", Json::Num(seed as f64));
    report.env("trace_duration_s", Json::Num(trace.duration_s()));

    let mut totals = (0usize, 0usize, 0usize, 0usize);
    let mut ran = 0usize;
    for m in ["open", "closed"] {
        if mode != "both" && mode != m {
            continue;
        }
        ran += 1;
        let fleet = spawn_fleet(shards, queue_depth, overflow_depth);
        let t0 = Instant::now();
        let outcomes = if m == "open" {
            run_open(&fleet.router, &trace)
        } else {
            run_closed(&fleet.router, &trace, workers)
        };
        let wall = t0.elapsed().as_secs_f64();
        let (c, r, s, e) =
            record_scenario(&mut report, m, trace.len(), &outcomes, &fleet, wall, shards);
        // The zero-dropped/zero-stuck contract the CI load-smoke job
        // relies on: every submission reached a terminal state, typed.
        anyhow::ensure!(
            outcomes.len() == trace.len(),
            "[{m}] lost requests: {} outcomes for {} submissions",
            outcomes.len(),
            trace.len()
        );
        anyhow::ensure!(e == 0, "[{m}] {e} requests errored or lost their stream");
        anyhow::ensure!(
            c + r + s == trace.len(),
            "[{m}] terminal states don't cover the trace: {c}+{r}+{s} != {}",
            trace.len()
        );
        let stats = fleet.router.stats();
        anyhow::ensure!(
            stats.overflow_len == 0,
            "[{m}] overflow queue still holds {} parked requests",
            stats.overflow_len
        );
        fleet.shutdown();
        totals = (totals.0 + c, totals.1 + r, totals.2 + s, totals.3 + e);
    }
    anyhow::ensure!(ran > 0, "--mode must be open, closed, or both");

    let path = report.write()?;
    println!("[json] {path}");
    println!(
        "[load_harness] ok: {} completed, {} rejected(429), {} saturated(503), 0 dropped/stuck \
         across {ran} scenario(s) on {shards} shards",
        totals.0, totals.1, totals.2
    );
    Ok(())
}
