//! Figure 4 — reconstruction error (max-abs, L2), K-side attention-score
//! error, and the value/output-side error |PV − PV̂| across
//! configurations. These numbers are substrate-independent: max-abs ≈
//! 0.00394 for U(-1,1) inputs, attention error ∝ √D, and the softmax
//! averaging drives the V-side output error well below the per-element
//! bound.

use kvq::bench::figures;

fn main() -> anyhow::Result<()> {
    let ctx = figures::FigCtx::from_env()?;
    let t = figures::fig4_table(&ctx)?;
    figures::emit(&t, "fig4_error");
    Ok(())
}
