//! Figure 4 — reconstruction error (max-abs, L2), K-side attention-score
//! error, and the value/output-side error |PV − PV̂| across
//! configurations. These numbers are substrate-independent: max-abs ≈
//! 0.00394 for U(-1,1) inputs, attention error ∝ √D, and the softmax
//! averaging drives the V-side output error well below the per-element
//! bound.
//!
//! Also emits the **policy sweep** (fig4b): per-policy
//! key/attention/value-output error columns for `uniform:int8`,
//! `uniform:int4`, `k8v4`, and `sink8` — the error half of the
//! non-uniform accuracy/memory frontier. The policy sweep needs no PJRT
//! artifacts, so it always runs; the artifact-backed per-shape table is
//! skipped (with a warning) when the runtime is unavailable.

use kvq::bench::figures;

fn main() -> anyhow::Result<()> {
    // Policy sweep first: pure-CPU, always available.
    figures::emit(&figures::fig4_policy_table(), "fig4_policy_error");

    // Artifact-backed per-shape sweep (attnerr probes run via PJRT).
    match figures::FigCtx::from_env() {
        Ok(ctx) => {
            let t = figures::fig4_table(&ctx)?;
            figures::emit(&t, "fig4_error");
        }
        Err(e) => {
            eprintln!("[fig4] skipping artifact-backed table (no PJRT runtime): {e:#}");
        }
    }
    Ok(())
}
