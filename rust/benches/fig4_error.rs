//! Figure 4 — reconstruction error (max-abs, L2) and attention-score
//! error across configurations. These numbers are substrate-independent:
//! max-abs ≈ 0.00394 for U(-1,1) inputs, attention error ∝ √D.

use kvq::bench::figures;

fn main() -> anyhow::Result<()> {
    let ctx = figures::FigCtx::from_env()?;
    let t = figures::fig4_table(&ctx)?;
    figures::emit(&t, "fig4_error");
    Ok(())
}
