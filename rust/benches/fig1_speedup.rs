//! Figure 1 — GPU kernel speedup over the CPU baseline, all Table-3
//! configurations, all four kernel variants.
//!
//! Default: CI-scaled shapes. `--full` / KVQ_BENCH_FULL=1: the paper's
//! exact sizes (up to 1B elements; several GB RAM and minutes of CPU
//! baseline — the paper's own CPU column took 79 s at the top size).

use kvq::bench::figures;

fn main() -> anyhow::Result<()> {
    let ctx = figures::FigCtx::from_env()?;
    println!(
        "[fig1] shapes={} set={} (pass --full for paper sizes)",
        ctx.shapes.len(),
        if ctx.full { "paper" } else { "ci" }
    );
    let rows = figures::measure_speedups(&ctx)?;
    figures::emit(&figures::fig1_table(&rows), "fig1_speedup");

    // The paper's headline ordering: vectorized best-or-tied, tiled ≈ naive.
    if let Some(last) = rows.last() {
        println!(
            "\n[fig1] largest config: vectorized {:.1}x vs naive {:.1}x vs cpu 1.0x",
            last.speedup("vectorized"),
            last.speedup("naive")
        );
    }
    Ok(())
}
