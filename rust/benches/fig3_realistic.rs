//! Figure 3 — GPU kernel time on the realistic LLM workloads (D ≥ 1024).
//! The paper reports a 6–58 ms band on a T4 at the full sizes.

use kvq::bench::figures;

fn main() -> anyhow::Result<()> {
    let ctx = figures::FigCtx::from_env()?;
    let rows = figures::measure_speedups_cached(&ctx)?;
    figures::emit(&figures::fig3_table(&rows), "fig3_realistic");
    Ok(())
}
