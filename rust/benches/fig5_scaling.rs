//! Figure 5 — speedup vs problem size: the series form of Fig 1, sorted by
//! element count, showing where each variant's speedup plateaus.

use kvq::bench::figures;

fn main() -> anyhow::Result<()> {
    let ctx = figures::FigCtx::from_env()?;
    let rows = figures::measure_speedups_cached(&ctx)?;
    figures::emit(&figures::fig5_table(&rows), "fig5_scaling");
    Ok(())
}
