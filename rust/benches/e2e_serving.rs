//! End-to-end serving bench: INT8 vs FP32 KV cache, full stack
//! (router → continuous batcher → PJRT artifacts → paged cache).
//!
//! The measurement the paper's future-work §8.2 asks for: token
//! throughput, TTFT, TPOT, and cache memory, with quantization as the
//! only variable — now also swept over the parallel-runtime worker count
//! (decode-wave gathers + prefill quantization fan-out).
//!
//! Flags: --model kvq-3m|kvq-25m --requests N --max-new N --concurrency N
//!        --threads N (skip the sweep, run one worker count)
//!
//! Emits `bench_results/BENCH_e2e_serving.json` (schema kvq-bench-v1).

use kvq::bench::workload::ServingWorkload;
use kvq::bench::BenchReport;
use kvq::coordinator::batcher::BatcherConfig;
use kvq::coordinator::engine::{self, EngineConfig};
use kvq::coordinator::request::collect_response;
use kvq::coordinator::router::{RoutePolicy, Router};
use kvq::kvcache::Precision;
use kvq::model::runner::{DecodeKernel, PjrtBackend};
use kvq::model::sample::SamplingParams;
use kvq::runtime::Runtime;
use kvq::util::args::Args;
use kvq::util::harness::{cell_f, cell_time, Table};
use kvq::util::json::Json;
use kvq::util::stats::Summary;
use std::rc::Rc;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let model = args.str_or("model", "kvq-3m");
    let n_requests = args.usize_or("requests", 16);
    let max_new = args.usize_or("max-new", 24);
    let concurrency = args.usize_or("concurrency", 4);
    let prompt_lo = args.usize_or("prompt-min", 16);
    let prompt_hi = args.usize_or("prompt-max", 64);
    let thread_sweep: Vec<usize> = if args.has("threads") {
        vec![args.usize_or("threads", 1)]
    } else {
        kvq::parallel::bench_thread_sweep()
    };

    let mut table = Table::new(
        &format!(
            "E2E serving: INT8 vs FP32 cache ({model}, {n_requests} reqs, {max_new} new tokens)"
        ),
        &[
            "precision", "threads", "cache MiB", "tok/s", "ttft p50", "ttft p99", "tpot p50",
            "e2e p50", "finished", "rejected",
        ],
    );
    let mut report = BenchReport::new("e2e_serving");
    report.env("model", model.as_str().into());
    report.env("requests", Json::Num(n_requests as f64));
    report.env("max_new", Json::Num(max_new as f64));

    for &threads in &thread_sweep {
        for precision in [Precision::Fp32, Precision::Int8] {
            let dir = kvq::runtime::default_artifact_dir();
            let m = model.clone();
            let ecfg = EngineConfig {
                precision,
                expected_concurrency: concurrency,
                parallelism: threads,
                batcher: BatcherConfig {
                    max_prefills_per_step: 2,
                    ..Default::default()
                },
                ..Default::default()
            };
            let (h, join) = engine::spawn(ecfg, move || {
                let rt = Rc::new(Runtime::new(&dir)?);
                Ok(Box::new(PjrtBackend::new(rt, &m, 0xA11CE, DecodeKernel::PlainXla)?)
                    as Box<dyn kvq::model::LmBackend>)
            });
            let mut router = Router::new(RoutePolicy::RoundRobin);
            router.add_engine(precision.name(), h.clone());

            // Deterministic Poisson workload; same seed for every cell.
            let wl = ServingWorkload::poisson(
                n_requests,
                1000.0, // effectively open-loop burst
                (prompt_lo, prompt_hi),
                max_new,
                256,
                42,
            );

            let t0 = Instant::now();
            let mut streams = Vec::new();
            for prompt in wl.prompts.iter() {
                let (_, rx) =
                    router.submit(prompt.clone(), max_new, SamplingParams::default())?;
                streams.push(rx);
            }
            let mut ttfts = Summary::new();
            let mut e2es = Summary::new();
            let mut tokens_total = 0usize;
            let mut finished = 0usize;
            let mut rejected = 0usize;
            for rx in &streams {
                let (tokens, reason, ttft, elapsed) = collect_response(rx);
                match reason {
                    kvq::coordinator::FinishReason::Rejected(_) => rejected += 1,
                    _ => {
                        finished += 1;
                        tokens_total += tokens.len();
                        ttfts.add(ttft);
                        e2es.add(elapsed);
                    }
                }
            }
            let wall = t0.elapsed().as_secs_f64();
            let snap = h.metrics.snapshot();
            // Cache memory from the engine's pool config.
            let cache_mib = {
                // recompute the default sizing for reporting
                let manifest =
                    kvq::runtime::Manifest::load(&kvq::runtime::default_artifact_dir())?;
                let mj = manifest
                    .models
                    .iter()
                    .find(|mj| mj.get("name").as_str() == Some(model.as_str()))
                    .unwrap();
                let spec = kvq::model::ModelSpec::from_json(mj)?;
                let blocks_per_seq = 2 * spec.layers * spec.max_seq.div_ceil(spec.block_size);
                let total = blocks_per_seq * concurrency;
                let per_block = precision
                    .bytes_for(spec.block_size * spec.heads * spec.head_dim);
                (total * per_block) as f64 / (1024.0 * 1024.0)
            };
            let tok_s = tokens_total as f64 / wall;

            table.row(&[
                precision.name().to_string(),
                threads.to_string(),
                format!("{cache_mib:.1}"),
                cell_f(tok_s, 1),
                cell_time(ttfts.percentile(50.0)),
                cell_time(ttfts.percentile(99.0)),
                cell_time(snap.tpot_p50),
                cell_time(e2es.percentile(50.0)),
                finished.to_string(),
                rejected.to_string(),
            ]);
            report.add(
                "e2e_serving",
                precision.name(),
                None,
                &[
                    ("threads", Json::Num(threads as f64)),
                    ("cache_mib", Json::Num(cache_mib)),
                    ("tok_per_s", Json::Num(tok_s)),
                    ("ttft_p50_s", Json::Num(ttfts.percentile(50.0))),
                    ("ttft_p99_s", Json::Num(ttfts.percentile(99.0))),
                    ("tpot_p50_s", Json::Num(snap.tpot_p50)),
                    ("e2e_p50_s", Json::Num(e2es.percentile(50.0))),
                    ("finished", Json::Num(finished as f64)),
                    ("rejected", Json::Num(rejected as f64)),
                ],
            );

            h.drain();
            join.join().ok();
        }
    }

    table.print();
    table.write_csv("bench_results/e2e_serving.csv").ok();
    println!("[csv] bench_results/e2e_serving.csv");
    let path = report.write()?;
    println!("[json] {path}");
    println!(
        "\nNote: identical decode math modulo cache precision; INT8's win is 4x cache \
         memory (column 3) at equal-or-better throughput — the paper's deployment claim."
    );
    Ok(())
}
