//! End-to-end serving bench: INT8 vs FP32 KV cache, full stack
//! (router → continuous batcher → PJRT artifacts → paged cache).
//!
//! The measurement the paper's future-work §8.2 asks for: token
//! throughput, TTFT, TPOT, and cache memory, with quantization as the
//! only variable — swept over the parallel-runtime worker count, plus an
//! **overload + shared-prefix scenario** (64 requests over 8 distinct
//! prompts on a deliberately undersized pool) comparing optimistic
//! admission (preemption + recompute + prefix cache) against worst-case
//! reservation on throughput, sustained concurrency, preemption count,
//! and prefix hit rate — plus the **decode-path scenario** (section
//! `decode_path`): staged gather-into-staging vs zero-copy block-native
//! fused attention, reporting decode ns/token and cache bytes/token and
//! asserting the two paths emit identical tokens — and the
//! **decode_batching scenario**: fused multi-query batched decode (auto)
//! vs the per-sequence path (off) on a shared-prefix wave, reporting
//! `speedup_vs_unbatched`, `mq_passes`, `blocks_deduped`, and cache
//! bytes/token, again with identical-token assertions — and the
//! **prefix_trie scenario**: a RAG-style workload (8 system prompts ×
//! several distinct suffixes + exact repeats) reporting the trie's
//! hit-rate and prefill-tokens-saved against the exact-match baseline
//! (full hits only), with byte-identical tokens vs a cache-disabled run
//! — and the **tiered_cache scenario**: long-context prompts on an
//! undersized pool with the compressed cold tier off vs on, asserting
//! byte-identical tokens while the tier-on run demotes, promotes, and
//! absorbs pool pressure without destroying cached prefixes.
//!
//! Flags: --model kvq-3m|kvq-25m --requests N --max-new N --concurrency N
//!        --threads N (skip the sweep, run one worker count)
//!        --smoke (CPU oracle backend, no artifacts needed — the CI
//!                 bench-smoke job runs this; emits BENCH_e2e_smoke.json)
//!
//! Emits `bench_results/BENCH_e2e_serving.json` (schema kvq-bench-v1).

use kvq::bench::workload::ServingWorkload;
use kvq::bench::BenchReport;
use kvq::coordinator::admission::{AdmissionConfig, AdmissionMode};
use kvq::coordinator::batcher::BatcherConfig;
use kvq::coordinator::engine::{self, DecodeBatching, EngineConfig};
use kvq::coordinator::request::collect_response;
use kvq::coordinator::router::{RoutePolicy, Router};
use kvq::kvcache::{PolicySpec, Precision};
use kvq::model::runner::{CpuBackend, DecodeKernel, PjrtBackend};
use kvq::model::sample::SamplingParams;
use kvq::model::weights::Weights;
use kvq::model::ModelSpec;
use kvq::quant::simd::KernelBackend;
use kvq::runtime::Runtime;
use kvq::util::args::Args;
use kvq::util::harness::{cell_f, cell_time, Table};
use kvq::util::json::Json;
use kvq::util::stats::Summary;
use std::rc::Rc;
use std::time::Instant;

/// Backend factory for one engine spawn: CPU oracle (smoke) or PJRT.
fn backend_factory(
    smoke: bool,
    model: &str,
) -> impl FnOnce() -> anyhow::Result<Box<dyn kvq::model::LmBackend>> + Send + 'static {
    let model = model.to_string();
    move || {
        if smoke {
            let spec = ModelSpec::test_tiny();
            let w = Weights::synthetic(&spec, 7);
            Ok(Box::new(CpuBackend::new(spec, w)) as Box<dyn kvq::model::LmBackend>)
        } else {
            let dir = kvq::runtime::default_artifact_dir();
            let rt = Rc::new(Runtime::new(&dir)?);
            Ok(Box::new(PjrtBackend::new(rt, &model, 0xA11CE, DecodeKernel::PlainXla)?)
                as Box<dyn kvq::model::LmBackend>)
        }
    }
}

fn scenario_spec(smoke: bool, model: &str) -> anyhow::Result<ModelSpec> {
    if smoke {
        return Ok(ModelSpec::test_tiny());
    }
    let manifest = kvq::runtime::Manifest::load(&kvq::runtime::default_artifact_dir())?;
    let mj = manifest
        .models
        .iter()
        .find(|mj| mj.get("name").as_str() == Some(model))
        .ok_or_else(|| anyhow::anyhow!("model {model:?} not in manifest"))?;
    ModelSpec::from_json(mj)
}

/// Overload + shared-prefix scenario: `n_requests` over `n_prompts`
/// distinct prompts against a pool sized for ~3 worst-case sequences.
fn overload_scenario(
    report: &mut BenchReport,
    table: &mut Table,
    smoke: bool,
    model: &str,
    n_requests: usize,
    n_prompts: usize,
) -> anyhow::Result<()> {
    let spec = scenario_spec(smoke, model)?;
    let prompt_len = spec.block_size;
    let max_new = (spec.max_seq - prompt_len).min(spec.max_seq / 2);
    let blocks_per_seq =
        2 * spec.layers * (prompt_len + max_new).div_ceil(spec.block_size);
    let num_blocks = blocks_per_seq * 3; // ~3 full sequences: heavy overload
    // Prefix budget: enough for every distinct prompt's blocks.
    let prompt_blocks = 2 * spec.layers * prompt_len.div_ceil(spec.block_size);
    let prefix_cache_blocks = prompt_blocks * n_prompts;

    // n_prompts distinct prompts, cycled across n_requests (deterministic).
    let wl = ServingWorkload::poisson(
        n_prompts,
        1000.0,
        (prompt_len, prompt_len),
        max_new,
        spec.vocab.min(256),
        7,
    );
    let prompts: Vec<Vec<i32>> =
        (0..n_requests).map(|i| wl.prompts[i % n_prompts].clone()).collect();

    for mode in [AdmissionMode::WorstCase, AdmissionMode::Optimistic] {
        let ecfg = EngineConfig {
            quant_policy: PolicySpec::uniform(Precision::Int8),
            num_blocks: Some(num_blocks),
            // Prefix sharing only helps the optimistic run: the contrast
            // below is "old scheduler" vs "new scheduler", not one knob.
            prefix_cache_blocks: if mode == AdmissionMode::Optimistic {
                prefix_cache_blocks
            } else {
                0
            },
            batcher: BatcherConfig {
                max_prefills_per_step: 4,
                admission: AdmissionConfig { mode, max_running: 16, ..Default::default() },
                ..Default::default()
            },
            ..Default::default()
        };
        let (h, join) = engine::spawn(ecfg, backend_factory(smoke, model));
        let mut router = Router::new(RoutePolicy::RoundRobin);
        router.add_engine("int8", h.clone());

        let t0 = Instant::now();
        let streams: Vec<_> = prompts
            .iter()
            .map(|p| router.submit(p.clone(), max_new, SamplingParams::default()).unwrap().1)
            .collect();
        let mut tokens_total = 0usize;
        let mut finished = 0usize;
        for rx in &streams {
            let (tokens, reason, ..) = collect_response(rx);
            match reason {
                kvq::coordinator::FinishReason::Rejected(_) => {}
                _ => {
                    finished += 1;
                    tokens_total += tokens.len();
                }
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        h.drain();
        join.join().ok();
        let snap = h.metrics.snapshot();
        let tok_s = tokens_total as f64 / wall;

        table.row(&[
            format!("overload/{}", mode.name()),
            "-".into(),
            format!("{num_blocks} blk"),
            cell_f(tok_s, 1),
            "-".into(),
            "-".into(),
            cell_time(snap.tpot_p50),
            "-".into(),
            finished.to_string(),
            (n_requests - finished).to_string(),
        ]);
        report.add(
            "overload_prefix",
            mode.name(),
            None,
            &[
                ("requests", Json::Num(n_requests as f64)),
                ("distinct_prompts", Json::Num(n_prompts as f64)),
                ("pool_blocks", Json::Num(num_blocks as f64)),
                ("tok_per_s", Json::Num(tok_s)),
                ("finished", Json::Num(finished as f64)),
                ("running_peak", Json::Num(snap.running_peak as f64)),
                ("preemptions", Json::Num(snap.preemptions as f64)),
                ("resumes", Json::Num(snap.resumes as f64)),
                ("recompute_tokens", Json::Num(snap.recompute_tokens as f64)),
                ("prefix_lookups", Json::Num(snap.prefix_lookups as f64)),
                ("prefix_hits", Json::Num(snap.prefix_hits as f64)),
                ("prefix_hit_rate", Json::Num(snap.prefix_hit_rate())),
            ],
        );
        println!(
            "[overload/{}] {} finished, peak {} running, {} preemptions, \
             prefix hit rate {:.2}",
            mode.name(),
            finished,
            snap.running_peak,
            snap.preemptions,
            snap.prefix_hit_rate()
        );
    }
    Ok(())
}

/// Staged vs zero-copy paged decode on the CPU oracle backend, plus the
/// kernel-backend contrast: the scalar pair pins the pre-SIMD data path
/// (asserted bit-identical tokens), the simd pair demonstrates the
/// per-backend determinism contract (byte-identical across reruns) and
/// records the SIMD decode ns/token. Every `decode_path` entry carries
/// `kernel_backend` (the knob) and `kernel_isa` (what it resolved to).
fn decode_path_scenario(report: &mut BenchReport, n_requests: usize) -> anyhow::Result<()> {
    let spec = ModelSpec::test_tiny();
    let prompt_len = spec.block_size;
    let max_new = (spec.max_seq - prompt_len) / 2;
    let wl = ServingWorkload::poisson(
        n_requests,
        1000.0,
        (prompt_len, prompt_len),
        max_new,
        spec.vocab.min(256),
        11,
    );
    let mut outputs: Vec<Vec<Vec<i32>>> = Vec::new();
    let runs = [
        ("staged", false, KernelBackend::Scalar),
        ("paged", true, KernelBackend::Scalar),
        ("paged_simd", true, KernelBackend::Simd),
        ("paged_simd_rerun", true, KernelBackend::Simd),
    ];
    for (label, paged, kb) in runs {
        let ecfg = EngineConfig {
            quant_policy: PolicySpec::uniform(Precision::Int8),
            paged_decode: paged,
            kernel_backend: kb,
            ..Default::default()
        };
        let (h, join) = engine::spawn(ecfg, backend_factory(true, "test-tiny"));
        let mut router = Router::new(RoutePolicy::RoundRobin);
        router.add_engine("int8", h.clone());
        let streams: Vec<_> = wl
            .prompts
            .iter()
            .map(|p| router.submit(p.clone(), max_new, SamplingParams::default()).unwrap().1)
            .collect();
        let tokens: Vec<Vec<i32>> = streams.iter().map(|rx| collect_response(rx).0).collect();
        h.drain();
        join.join().ok();
        let snap = h.metrics.snapshot();
        report.add(
            "decode_path",
            label,
            None,
            &[
                ("kernel_backend", kb.name().into()),
                ("kernel_isa", kb.resolve().name().into()),
                ("decode_ns_per_token", Json::Num(snap.decode_ns_per_token())),
                ("gather_secs", Json::Num(snap.gather_secs)),
                ("attend_secs", Json::Num(snap.attend_secs)),
                ("cache_bytes_per_token", Json::Num(snap.cache_bytes_per_token())),
                ("decode_steps", Json::Num(snap.decode_steps as f64)),
                ("tokens", Json::Num(snap.tokens_generated as f64)),
            ],
        );
        println!(
            "[decode_path/{label}:{}] {:.0} ns/token decode ({:.0} gathered + {:.0} attended \
             µs total), {:.0} cache bytes/token",
            kb.name(),
            snap.decode_ns_per_token(),
            snap.gather_secs * 1e6,
            snap.attend_secs * 1e6,
            snap.cache_bytes_per_token()
        );
        outputs.push(tokens);
    }
    assert_eq!(
        outputs[0], outputs[1],
        "scalar paged decode must be bit-identical to the scalar staged path (pre-SIMD bytes)"
    );
    assert_eq!(
        outputs[2], outputs[3],
        "simd decode must be byte-identical across reruns (per-backend contract)"
    );
    println!("[decode_path] scalar staged==paged and simd rerun identity hold ✓");
    Ok(())
}

/// Fused multi-query batched decode vs the per-sequence path on a wave
/// of requests sharing a COW prefix (duplicate prompts + prefix cache,
/// so decode waves reference shared physical blocks). `off` pins the
/// baseline; `auto` must emit byte-identical tokens while reading fewer
/// cache bytes per token (shared blocks decoded once per wave). Records
/// `speedup_vs_unbatched` from decode ns/token plus the new `mq_passes`
/// and `blocks_deduped` gauges; runs in `--smoke` so CI's
/// `BENCH_e2e_smoke.json` carries a `decode_batching` section.
fn decode_batching_scenario(report: &mut BenchReport, n_requests: usize) -> anyhow::Result<()> {
    let spec = ModelSpec::test_tiny();
    let prompt_len = spec.block_size;
    let max_new = (spec.max_seq - prompt_len) / 2;
    let n_prompts = 2usize;
    let prompt_blocks = 2 * spec.layers * prompt_len.div_ceil(spec.block_size);
    let wl = ServingWorkload::poisson(
        n_prompts,
        1000.0,
        (prompt_len, prompt_len),
        max_new,
        spec.vocab.min(256),
        17,
    );
    // Duplicate prompts: repeats fork the prefix cache entry, so the
    // decode wave shares physical prefix blocks across members.
    let prompts: Vec<Vec<i32>> =
        (0..n_requests).map(|i| wl.prompts[i % n_prompts].clone()).collect();
    let mut results: Vec<(Vec<Vec<i32>>, kvq::coordinator::MetricsSnapshot)> = Vec::new();
    for mode in [DecodeBatching::Off, DecodeBatching::Auto] {
        let ecfg = EngineConfig {
            quant_policy: PolicySpec::uniform(Precision::Int8),
            prefix_cache_blocks: prompt_blocks * n_prompts,
            decode_batching: mode,
            ..Default::default()
        };
        let (h, join) = engine::spawn(ecfg, backend_factory(true, "test-tiny"));
        let mut router = Router::new(RoutePolicy::RoundRobin);
        router.add_engine("int8", h.clone());
        let streams: Vec<_> = prompts
            .iter()
            .map(|p| router.submit(p.clone(), max_new, SamplingParams::default()).unwrap().1)
            .collect();
        let tokens: Vec<Vec<i32>> = streams.iter().map(|rx| collect_response(rx).0).collect();
        h.drain();
        join.join().ok();
        results.push((tokens, h.metrics.snapshot()));
    }
    let (off_tokens, off_snap) = &results[0];
    let (auto_tokens, auto_snap) = &results[1];
    assert_eq!(
        off_tokens, auto_tokens,
        "batched decode must emit byte-identical tokens to the per-sequence path"
    );
    let speedup = off_snap.decode_ns_per_token() / auto_snap.decode_ns_per_token();
    for (label, snap) in [("off", off_snap), ("auto", auto_snap)] {
        report.add(
            "decode_batching",
            label,
            None,
            &[
                (
                    "speedup_vs_unbatched",
                    Json::Num(if label == "auto" { speedup } else { 1.0 }),
                ),
                ("decode_ns_per_token", Json::Num(snap.decode_ns_per_token())),
                ("mq_passes", Json::Num(snap.mq_passes as f64)),
                ("blocks_deduped", Json::Num(snap.blocks_deduped as f64)),
                ("cache_bytes_per_token", Json::Num(snap.cache_bytes_per_token())),
                ("prefix_hits", Json::Num(snap.prefix_hits as f64)),
                ("tokens", Json::Num(snap.tokens_generated as f64)),
            ],
        );
    }
    assert!(
        auto_snap.mq_passes > 0,
        "auto run must take the fused multi-query path on a concurrent wave"
    );
    assert!(
        auto_snap.cache_bytes_read <= off_snap.cache_bytes_read,
        "shared-prefix wave must not read more cache bytes batched than per-sequence"
    );
    println!(
        "[decode_batching] tokens identical ✓  {:.2}x vs unbatched, {} mq passes, \
         {} blocks deduped, {:.0} vs {:.0} cache bytes/token",
        speedup,
        auto_snap.mq_passes,
        auto_snap.blocks_deduped,
        auto_snap.cache_bytes_per_token(),
        off_snap.cache_bytes_per_token()
    );
    Ok(())
}

/// Radix-trie prefix cache on a RAG-style workload: `n_sys` distinct
/// two-block system prompts, each followed by several distinct suffixes
/// plus one exact repeat. An exact-match cache only saves the repeats
/// (the trie's full hits reproduce exactly that set); the trie also
/// serves every shared system prefix from forked cached blocks, running
/// suffix prefill for the rest. Reports saved prefill tokens and
/// hit-rate for both, asserting the trie lands strictly above the
/// exact-match baseline with tokens byte-identical to a cache-disabled
/// run. Runs in `--smoke` so CI's `BENCH_e2e_smoke.json` carries a
/// `prefix_trie` section.
fn prefix_trie_scenario(report: &mut BenchReport) -> anyhow::Result<()> {
    let spec = ModelSpec::test_tiny();
    let bs = spec.block_size;
    let (sys_len, suffix_len) = (2 * bs, bs);
    let max_new = (spec.max_seq - sys_len - suffix_len).min(6);
    let (n_sys, n_suffix) = (8usize, 3usize);
    let vocab = spec.vocab;
    let mut prompts: Vec<Vec<i32>> = Vec::new();
    for i in 0..n_sys {
        let sys: Vec<i32> =
            (0..sys_len).map(|t| ((i * 31 + t * 7 + 5) % vocab) as i32).collect();
        for j in 0..n_suffix {
            let mut p = sys.clone();
            p.extend(
                (0..suffix_len).map(|t| ((i * 13 + j * 17 + t * 3 + 11) % vocab) as i32),
            );
            prompts.push(p);
        }
        // Exact repeat of this system prompt's first suffix: the one
        // request an exact-match cache would also have served.
        prompts.push(prompts[prompts.len() - n_suffix].clone());
    }
    let prompt_tokens: usize = prompts.iter().map(|p| p.len()).sum();

    let run = |budget: usize| {
        let ecfg = EngineConfig {
            quant_policy: PolicySpec::uniform(Precision::Int8),
            // Roomy pool: the contrast here is cache policy, not pool
            // pressure (trie entries pin ~20 blocks per system prompt).
            num_blocks: Some(1024),
            prefix_cache_blocks: budget,
            ..Default::default()
        };
        let (h, join) = engine::spawn(ecfg, backend_factory(true, "test-tiny"));
        let mut router = Router::new(RoutePolicy::RoundRobin);
        router.add_engine("trie", h.clone());
        let t0 = Instant::now();
        let streams: Vec<_> = prompts
            .iter()
            .map(|p| router.submit(p.clone(), max_new, SamplingParams::default()).unwrap().1)
            .collect();
        let tokens: Vec<Vec<i32>> = streams.iter().map(|rx| collect_response(rx).0).collect();
        let wall = t0.elapsed().as_secs_f64();
        h.drain();
        join.join().ok();
        (tokens, h.metrics.snapshot(), wall)
    };

    let (base_tokens, _, base_wall) = run(0);
    let (trie_tokens, snap, trie_wall) = run(512);
    assert_eq!(
        base_tokens, trie_tokens,
        "trie-cached generations must be byte-identical to the uncached run"
    );
    assert!(snap.prefix_partial_hits > 0, "shared system prefixes must partially hit");
    // Full hits are exactly what an exact-match cache would have served.
    let exact_saved = snap.prefix_hits * (sys_len + suffix_len) as u64;
    let trie_rate = snap.prefix_saved_tokens as f64 / prompt_tokens as f64;
    let exact_rate = exact_saved as f64 / prompt_tokens as f64;
    assert!(
        snap.prefix_saved_tokens > exact_saved,
        "trie must save strictly more prefill tokens than exact matching \
         ({} vs {})",
        snap.prefix_saved_tokens,
        exact_saved
    );
    for (label, saved, rate, partial) in [
        ("exact_match_baseline", exact_saved, exact_rate, 0u64),
        ("trie", snap.prefix_saved_tokens, trie_rate, snap.prefix_partial_hits),
    ] {
        report.add(
            "prefix_trie",
            label,
            None,
            &[
                ("requests", Json::Num(prompts.len() as f64)),
                ("prompt_tokens", Json::Num(prompt_tokens as f64)),
                ("prefill_tokens_saved", Json::Num(saved as f64)),
                ("hit_rate_token_share", Json::Num(rate)),
                ("full_hits", Json::Num(snap.prefix_hits as f64)),
                ("partial_hits", Json::Num(partial as f64)),
                ("trie_nodes", Json::Num(snap.prefix_trie_nodes as f64)),
                ("uncached_wall_s", Json::Num(base_wall)),
                ("wall_s", Json::Num(trie_wall)),
            ],
        );
    }
    println!(
        "[prefix_trie] tokens identical ✓  trie saved {}/{} prompt tokens \
         ({:.2} rate) vs {} exact-match ({:.2}); {} partial hits, {} trie nodes",
        snap.prefix_saved_tokens,
        prompt_tokens,
        trie_rate,
        exact_saved,
        exact_rate,
        snap.prefix_partial_hits,
        snap.prefix_trie_nodes
    );
    Ok(())
}

/// Tiered-cache scenario: long-context prompts (3 of the 4 blocks
/// test-tiny's max_seq allows) on a deliberately undersized pool, cold
/// tier off vs on. Three phases — warm two prompts into the trie,
/// pressure-burst two fresh prompts concurrently (forces the warm
/// entries out of the hot pool: destroyed with the tier off, demoted to
/// the compressed cold tier with it on), then repeat the warm prompts
/// (promotions). The two runs must emit byte-identical tokens; the
/// tier-on run reports demotions / promotions / compression ratio /
/// promote latency. Runs in `--smoke` so CI's `BENCH_e2e_smoke.json`
/// carries a `tiered_cache` section.
fn tiered_cache_scenario(report: &mut BenchReport) -> anyhow::Result<()> {
    let spec = ModelSpec::test_tiny();
    let bs = spec.block_size;
    let prompt_len = 3 * bs; // long context: 3 of the 4 blocks available
    let max_new = spec.max_seq - prompt_len;
    let blocks_per_seq = 2 * spec.layers * spec.max_seq.div_ceil(bs);
    let num_blocks = blocks_per_seq * 5 / 2; // ~2.5 sequences: undersized
    let vocab = spec.vocab;
    let prompt = |tag: usize| -> Vec<i32> {
        (0..prompt_len).map(|j| ((tag * 11 + j * 5 + 3) % vocab) as i32).collect()
    };
    let warm: Vec<Vec<i32>> = vec![prompt(1), prompt(2)];
    let fresh: Vec<Vec<i32>> = vec![prompt(3), prompt(4)];

    let run = |cold_blocks: usize| {
        let ecfg = EngineConfig {
            quant_policy: PolicySpec::uniform(Precision::Int8),
            num_blocks: Some(num_blocks),
            prefix_cache_blocks: 64,
            cold_tier_blocks: Some(cold_blocks),
            prefetch_depth: 2,
            batcher: BatcherConfig { max_prefills_per_step: 2, ..Default::default() },
            ..Default::default()
        };
        let (h, join) = engine::spawn(ecfg, backend_factory(true, "test-tiny"));
        let mut router = Router::new(RoutePolicy::RoundRobin);
        router.add_engine("tier", h.clone());
        let mut outputs: Vec<Vec<i32>> = Vec::new();
        for p in &warm {
            let (_, rx) = router.submit(p.clone(), max_new, SamplingParams::default()).unwrap();
            outputs.push(collect_response(&rx).0);
        }
        let streams: Vec<_> = fresh
            .iter()
            .map(|p| router.submit(p.clone(), max_new, SamplingParams::default()).unwrap().1)
            .collect();
        for rx in &streams {
            outputs.push(collect_response(rx).0);
        }
        for p in &warm {
            let (_, rx) = router.submit(p.clone(), max_new, SamplingParams::default()).unwrap();
            outputs.push(collect_response(&rx).0);
        }
        h.drain();
        join.join().ok();
        (outputs, h.metrics.snapshot())
    };

    let (off_tokens, off_snap) = run(0);
    let (on_tokens, on_snap) = run(num_blocks);
    assert_eq!(
        off_tokens,
        on_tokens,
        "tiered run must emit byte-identical tokens to the tier-off run"
    );
    assert!(on_snap.tier.demotions > 0, "undersized pool must demote the warm prefixes");
    assert!(on_snap.tier.promotions > 0, "repeated long prompts must promote from cold");
    assert!(
        on_snap.tier.preemptions_avoided > 0,
        "pool pressure must be absorbed by demotion, not preemption or eviction"
    );
    for (label, snap) in [("off", &off_snap), ("on", &on_snap)] {
        let promote_latency = if snap.tier.promotions > 0 {
            snap.tier.promote_secs / snap.tier.promotions as f64
        } else {
            0.0
        };
        report.add(
            "tiered_cache",
            label,
            None,
            &[
                ("pool_blocks", Json::Num(num_blocks as f64)),
                ("prompt_len", Json::Num(prompt_len as f64)),
                ("preemptions", Json::Num(snap.preemptions as f64)),
                ("preemptions_avoided", Json::Num(snap.tier.preemptions_avoided as f64)),
                ("demotions", Json::Num(snap.tier.demotions as f64)),
                ("promotions", Json::Num(snap.tier.promotions as f64)),
                ("prefetch_hits", Json::Num(snap.tier.prefetch_hits as f64)),
                ("prefetch_misses", Json::Num(snap.tier.prefetch_misses as f64)),
                ("compression_ratio", Json::Num(snap.tier.compression_ratio())),
                ("promote_latency_s", Json::Num(promote_latency)),
                ("prefix_saved_tokens", Json::Num(snap.prefix_saved_tokens as f64)),
            ],
        );
    }
    println!(
        "[tiered_cache] tokens identical ✓  {} demotions, {} promotions, {:.2}x cold \
         compression, {} reclaims absorbed without preemption",
        on_snap.tier.demotions,
        on_snap.tier.promotions,
        on_snap.tier.compression_ratio(),
        on_snap.tier.preemptions_avoided
    );
    Ok(())
}

/// Policy sweep on the CPU oracle: serve the same workload under each
/// named quantization policy (`uniform:int8`, `uniform:int4`, `k8v4`,
/// `sink8`) and record throughput, decode ns/token, cache bytes/token,
/// and the per-precision cache byte split from `GET /metrics`. Mixed
/// policies and INT4 ride the zero-copy paged path; runs in `--smoke`
/// so CI's `BENCH_e2e_smoke.json` carries a `policy_sweep` section.
fn policy_sweep_scenario(report: &mut BenchReport, n_requests: usize) -> anyhow::Result<()> {
    let spec = ModelSpec::test_tiny();
    let prompt_len = spec.block_size;
    let max_new = (spec.max_seq - prompt_len) / 2;
    let wl = ServingWorkload::poisson(
        n_requests,
        1000.0,
        (prompt_len, prompt_len),
        max_new,
        spec.vocab.min(256),
        13,
    );
    for policy in [
        PolicySpec::Uniform(Precision::Int8),
        PolicySpec::Uniform(Precision::Int4),
        PolicySpec::K8V4,
        PolicySpec::Sink8 { sink_layers: 1 },
    ] {
        let label = policy.name();
        // Per-precision cache footprint of one full sequence under this
        // policy (closed-form: the engine's end-of-run gauges read 0 —
        // finished sequences are freed before the final step books them).
        let resolved = policy.resolve(spec.layers, spec.heads, spec.head_dim)?;
        let seq_bytes =
            resolved.payload_bytes_by_precision(spec.head_dim, prompt_len + max_new);
        let ecfg = EngineConfig { quant_policy: policy, ..Default::default() };
        let (h, join) = engine::spawn(ecfg, backend_factory(true, "test-tiny"));
        let mut router = Router::new(RoutePolicy::RoundRobin);
        router.add_engine("sweep", h.clone());
        let t0 = Instant::now();
        let streams: Vec<_> = wl
            .prompts
            .iter()
            .map(|p| router.submit(p.clone(), max_new, SamplingParams::default()).unwrap().1)
            .collect();
        let mut tokens_total = 0usize;
        for rx in &streams {
            tokens_total += collect_response(rx).0.len();
        }
        let wall = t0.elapsed().as_secs_f64();
        h.drain();
        join.join().ok();
        let snap = h.metrics.snapshot();
        report.add(
            "policy_sweep",
            &label,
            None,
            &[
                ("tok_per_s", Json::Num(tokens_total as f64 / wall)),
                ("decode_ns_per_token", Json::Num(snap.decode_ns_per_token())),
                ("cache_bytes_per_token", Json::Num(snap.cache_bytes_per_token())),
                ("seq_cache_bytes_fp32", Json::Num(seq_bytes[0] as f64)),
                ("seq_cache_bytes_int8", Json::Num(seq_bytes[1] as f64)),
                ("seq_cache_bytes_int4", Json::Num(seq_bytes[2] as f64)),
                ("tokens", Json::Num(snap.tokens_generated as f64)),
            ],
        );
        println!(
            "[policy_sweep/{label}] {:.1} tok/s, {:.0} ns/token decode, \
             {:.0} cache bytes/token",
            tokens_total as f64 / wall,
            snap.decode_ns_per_token(),
            snap.cache_bytes_per_token()
        );
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let smoke = args.has("smoke");
    let model = args.str_or("model", "kvq-3m");
    let n_requests = args.usize_or("requests", 16);
    let max_new = args.usize_or("max-new", 24);
    let concurrency = args.usize_or("concurrency", 4);
    let prompt_lo = args.usize_or("prompt-min", 16);
    let prompt_hi = args.usize_or("prompt-max", 64);
    let overload_requests = args.usize_or("overload-requests", 64);
    let overload_prompts = args.usize_or("overload-prompts", 8);
    let thread_sweep: Vec<usize> = if args.has("threads") {
        vec![args.usize_or("threads", 1)]
    } else {
        kvq::parallel::bench_thread_sweep()
    };

    let mut table = Table::new(
        &format!(
            "E2E serving: INT8 vs FP32 cache ({model}, {n_requests} reqs, {max_new} new tokens)"
        ),
        &[
            "precision", "threads", "cache MiB", "tok/s", "ttft p50", "ttft p99", "tpot p50",
            "e2e p50", "finished", "rejected",
        ],
    );
    let mut report = BenchReport::new(if smoke { "e2e_smoke" } else { "e2e_serving" });
    report.env("model", model.as_str().into());
    report.env("requests", Json::Num(n_requests as f64));
    report.env("max_new", Json::Num(max_new as f64));
    report.env("smoke", Json::Bool(smoke));

    // The INT8-vs-FP32 sweep needs the PJRT artifacts; the smoke run
    // (CI) skips straight to the scheduler scenario on the CPU oracle.
    if !smoke {
        let spec = scenario_spec(false, &model)?;
        for &threads in &thread_sweep {
            for precision in [Precision::Fp32, Precision::Int8] {
                let m = model.clone();
                let ecfg = EngineConfig {
                    quant_policy: PolicySpec::uniform(precision),
                    expected_concurrency: concurrency,
                    parallelism: threads,
                    batcher: BatcherConfig {
                        max_prefills_per_step: 2,
                        ..Default::default()
                    },
                    ..Default::default()
                };
                let (h, join) = engine::spawn(ecfg, backend_factory(false, &m));
                let mut router = Router::new(RoutePolicy::RoundRobin);
                router.add_engine(precision.name(), h.clone());

                // Deterministic Poisson workload; same seed for every cell.
                let wl = ServingWorkload::poisson(
                    n_requests,
                    1000.0, // effectively open-loop burst
                    (prompt_lo, prompt_hi),
                    max_new,
                    256,
                    42,
                );

                let t0 = Instant::now();
                let mut streams = Vec::new();
                for prompt in wl.prompts.iter() {
                    let (_, rx) =
                        router.submit(prompt.clone(), max_new, SamplingParams::default())?;
                    streams.push(rx);
                }
                let mut ttfts = Summary::new();
                let mut e2es = Summary::new();
                let mut tokens_total = 0usize;
                let mut finished = 0usize;
                let mut rejected = 0usize;
                for rx in &streams {
                    let (tokens, reason, ttft, elapsed) = collect_response(rx);
                    match reason {
                        kvq::coordinator::FinishReason::Rejected(_) => rejected += 1,
                        _ => {
                            finished += 1;
                            tokens_total += tokens.len();
                            ttfts.add(ttft);
                            e2es.add(elapsed);
                        }
                    }
                }
                let wall = t0.elapsed().as_secs_f64();
                let snap = h.metrics.snapshot();
                // Cache memory from the engine's pool config (spec loaded
                // once above — it is loop-invariant).
                let cache_mib = {
                    let blocks_per_seq =
                        2 * spec.layers * spec.max_seq.div_ceil(spec.block_size);
                    let total = blocks_per_seq * concurrency;
                    let per_block = precision
                        .bytes_for(spec.block_size * spec.heads * spec.head_dim);
                    (total * per_block) as f64 / (1024.0 * 1024.0)
                };
                let tok_s = tokens_total as f64 / wall;

                table.row(&[
                    precision.name().to_string(),
                    threads.to_string(),
                    format!("{cache_mib:.1}"),
                    cell_f(tok_s, 1),
                    cell_time(ttfts.percentile(50.0)),
                    cell_time(ttfts.percentile(99.0)),
                    cell_time(snap.tpot_p50),
                    cell_time(e2es.percentile(50.0)),
                    finished.to_string(),
                    rejected.to_string(),
                ]);
                report.add(
                    "e2e_serving",
                    precision.name(),
                    None,
                    &[
                        ("threads", Json::Num(threads as f64)),
                        ("cache_mib", Json::Num(cache_mib)),
                        ("tok_per_s", Json::Num(tok_s)),
                        ("ttft_p50_s", Json::Num(ttfts.percentile(50.0))),
                        ("ttft_p99_s", Json::Num(ttfts.percentile(99.0))),
                        ("tpot_p50_s", Json::Num(snap.tpot_p50)),
                        ("e2e_p50_s", Json::Num(e2es.percentile(50.0))),
                        ("finished", Json::Num(finished as f64)),
                        ("rejected", Json::Num(rejected as f64)),
                    ],
                );

                h.drain();
                join.join().ok();
            }
        }
    }

    // Decode data-path contrast: staged copies vs zero-copy block-native
    // fused attention (CPU backend; runs in --smoke for the CI artifact).
    decode_path_scenario(&mut report, args.usize_or("decode-path-requests", 6))?;

    // Fused multi-query batched decode vs per-sequence on a shared-prefix
    // wave (CPU backend; runs in --smoke for the CI artifact).
    decode_batching_scenario(&mut report, args.usize_or("decode-batching-requests", 6))?;

    // Radix-trie prefix cache vs exact matching on a RAG workload (CPU
    // backend; runs in --smoke for the CI artifact).
    prefix_trie_scenario(&mut report)?;

    // Tiered cache: long-context prompts on an undersized pool, cold
    // tier off vs on (CPU backend; runs in --smoke for the CI artifact).
    tiered_cache_scenario(&mut report)?;

    // Quantization-policy sweep (CPU backend; runs in --smoke too).
    policy_sweep_scenario(&mut report, args.usize_or("policy-sweep-requests", 4))?;

    // Scheduler scenario: optimistic admission + preemption + prefix
    // sharing vs worst-case reservation, same pool, same workload.
    overload_scenario(
        &mut report,
        &mut table,
        smoke,
        &model,
        overload_requests,
        overload_prompts,
    )?;

    table.print();
    table.write_csv("bench_results/e2e_serving.csv").ok();
    println!("[csv] bench_results/e2e_serving.csv");
    let path = report.write()?;
    println!("[json] {path}");
    println!(
        "\nNote: identical decode math modulo cache precision; INT8's win is 4x cache \
         memory at equal-or-better throughput, and the overload scenario shows the \
         scheduler converting that headroom into sustained concurrency (optimistic \
         admission + preemption + prefix sharing) — the paper's deployment claim."
    );
    Ok(())
}
