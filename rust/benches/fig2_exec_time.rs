//! Figure 2 — absolute execution time, CPU vs GPU-analog, across problem
//! sizes (the paper plots this log-log; the emitted CSV carries the raw
//! series).

use kvq::bench::figures;

fn main() -> anyhow::Result<()> {
    let ctx = figures::FigCtx::from_env()?;
    let rows = figures::measure_speedups_cached(&ctx)?;
    figures::emit(&figures::fig2_table(&rows), "fig2_exec_time");
    Ok(())
}
