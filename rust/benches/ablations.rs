//! Ablations for the design choices DESIGN.md calls out:
//!
//! A1. per-channel vs per-tensor scales (why eq. 6 is per-column)
//! A2. frozen-prefill scales vs post-hoc requantization (serving policy)
//! A3. scale-computation algorithms (paper's strided loop vs row-sweep vs
//!     threaded), swept over the {1, 2, N_phys} thread set
//! A4. CPU quantize variants + the multi-threaded variant per thread count
//! A5. Pallas vectorized artifact vs plain-XLA `quantize_ref` codegen
//! A6. INT4 vs INT8: error/memory trade (paper §8.1)
//! A7. host-side row quantization vs offloading a (1, D) row to PJRT
//!     (why the cache writer runs on the host)
//! A8. dequantize: serial vs the parallel runtime per thread count
//! A9. fused INT8 attention: dequant·dot fused into the score pass
//!     (zero-copy paged decode) vs dequantize-then-dot, across the four
//!     kernel variants (runs in --smoke: the CI artifact carries the
//!     kernel sweep)
//! A10. kernel_backend: the runtime-dispatched SIMD backend (AVX2/NEON)
//!     vs the four scalar variants on the fused INT8 dot + softmax·V
//!     accumulation at d ∈ {64, 128, 4096} (runs in --smoke — the perf
//!     trajectory records real numbers per push)
//! A11. decode_batching: fused multi-query batched decode vs W
//!     independent per-sequence calls, wave widths {1, 4, 16} × shared
//!     COW-prefix fraction {0, 0.5, 1.0} — records
//!     `speedup_vs_unbatched` plus the amortized cache-byte footprint
//!     (runs in --smoke)
//! A12. scale granularity: one eq.-6 grid frozen over the whole prompt
//!     (pre-refactor serving policy) vs per-block grids frozen over each
//!     block's own rows with decode rows clamping into the last block's
//!     grid (the paged cache's policy) — fig4-style key / attention /
//!     value-output error plus the encode overhead (runs in --smoke)
//! A13. tier_sweep: tiered KV cache on the serving engine — hot-pool
//!     fraction {1.0, 0.5, 0.25} × cold tier {off, on} on a warm →
//!     pressure-burst → repeat workload (k8v4 policy, so the physical
//!     sub-pool footprint is also asserted strictly below the padded
//!     widest-stream baseline). Records preemptions, preemptions
//!     avoided (reclaims absorbed by demotion), demotions, promotions,
//!     compression ratio, and promote latency; every cell's tokens must
//!     be byte-identical to the unconstrained run (runs in --smoke)
//!
//! Emits `bench_results/BENCH_ablations.json` (schema kvq-bench-v1; see
//! rust/README.md). `--smoke` runs a tiny subset on the smallest CI shape
//! and writes `BENCH_smoke.json` instead — the CI bench-smoke job uploads
//! that artifact so perf is visible PR-over-PR.

use kvq::bench::workload::Workload;
use kvq::bench::BenchReport;
use kvq::config::shapes::ShapeRegistry;
use kvq::parallel;
use kvq::quant::{self, Fp32Matrix, Int8Matrix, Variant};
use kvq::runtime::Runtime;
use kvq::util::harness::{cell_f, cell_time, Bencher, Table};
use kvq::util::json::Json;
use std::rc::Rc;

fn main() -> anyhow::Result<()> {
    let args = kvq::util::args::Args::parse();
    let smoke = args.bool_or("smoke", false);
    let reg = ShapeRegistry::load_default()?;
    // Smoke: smallest CI shape + quick timing policy so the job stays
    // cheap; full: the scaled realistic shape.
    let shape = if smoke { reg.ci[0].clone() } else { reg.ci[4].clone() };
    let wl = Workload::uniform(&shape, 0xAB1);
    let bencher = if smoke { Bencher::quick() } else { Bencher::default() };
    let sweep = parallel::bench_thread_sweep();

    let mut report = BenchReport::new(if smoke { "smoke" } else { "ablations" });
    report.env("smoke", Json::Bool(smoke));
    report.env("shape", shape.tag().as_str().into());
    report.env(
        "thread_sweep",
        Json::Arr(sweep.iter().map(|&t| Json::Num(t as f64)).collect()),
    );

    // A1: per-channel vs per-tensor on outlier-bearing data.
    if !smoke {
        let mut k = Fp32Matrix::random_uniform(4096, 256, -1.0, 1.0, 0xA1);
        for t in 0..k.rows {
            k.data[t * k.cols] *= 100.0; // one hot channel
        }
        let pc = quant::dequantize(&quant::quantize_fused(&k));
        let pt = quant::dequantize(&quant::tensorwise::quantize_tensorwise(&k));
        let mut t1 = Table::new(
            "A1 — per-channel vs per-tensor scales (1 outlier channel x100)",
            &["scheme", "max_abs_err (normal cols)", "l2_err"],
        );
        let err_on_normal = |rec: &Fp32Matrix| {
            let mut e = 0.0f64;
            for t in 0..k.rows {
                for d in 1..k.cols {
                    e = e.max((k.at(t, d) - rec.at(t, d)).abs() as f64);
                }
            }
            e
        };
        for (name, rec) in [("per-channel", &pc), ("per-tensor", &pt)] {
            t1.row(&[
                name.into(),
                cell_f(err_on_normal(rec), 6),
                cell_f(quant::l2_error(&k, rec), 3),
            ]);
            report.add(
                "a1_scales_granularity",
                name,
                None,
                &[
                    ("max_abs_err_normal_cols", Json::Num(err_on_normal(rec))),
                    ("l2_err", Json::Num(quant::l2_error(&k, rec))),
                ],
            );
        }
        kvq::bench::figures::emit(&t1, "ablation_a1_scales_granularity");
    }

    // A2: frozen-scale streaming vs post-hoc requantization.
    if !smoke {
        // Simulate decode: scales frozen on the first half ("prompt"),
        // second half ("generated") quantized with frozen vs exact scales.
        let k = Fp32Matrix::random_normal(4096, 256, 1.0, 0xA2);
        let half = k.rows / 2;
        let prompt = Fp32Matrix::from_vec(half, k.cols, k.data[..half * k.cols].to_vec());
        let frozen_scales = quant::compute_scales(&prompt);
        let exact_scales = quant::compute_scales(&k);
        let mut q_frozen = Int8Matrix::zeros(k.rows, k.cols);
        let mut q_exact = Int8Matrix::zeros(k.rows, k.cols);
        quant::quantize::quantize_vectorized(&k, &frozen_scales, &mut q_frozen);
        quant::quantize::quantize_vectorized(&k, &exact_scales, &mut q_exact);
        let rec_frozen = quant::dequantize(&q_frozen);
        let rec_exact = quant::dequantize(&q_exact);
        let mut t2 = Table::new(
            "A2 — frozen prompt scales vs post-hoc requantization (N(0,1) keys)",
            &["policy", "max_abs_err", "l2_err", "attn_err"],
        );
        let q = Fp32Matrix::random_normal(32, 256, 1.0, 0x99);
        for (name, rec) in [("frozen (serving)", &rec_frozen), ("post-hoc (paper)", &rec_exact)] {
            t2.row(&[
                name.into(),
                cell_f(quant::max_abs_error(&k, rec), 5),
                cell_f(quant::l2_error(&k, rec), 3),
                cell_f(quant::attention_score_error(&q, &k, rec), 5),
            ]);
            report.add(
                "a2_frozen_scales",
                name,
                None,
                &[
                    ("max_abs_err", Json::Num(quant::max_abs_error(&k, rec))),
                    ("l2_err", Json::Num(quant::l2_error(&k, rec))),
                    ("attn_err", Json::Num(quant::attention_score_error(&q, &k, rec))),
                ],
            );
        }
        kvq::bench::figures::emit(&t2, "ablation_a2_frozen_scales");
    }

    // A3: scale computation algorithms, parallel swept over thread counts.
    {
        let mut t3 = Table::new(
            &format!("A3 — scale computation on {} ({} elements)", shape.tag(), wl.elements()),
            &["algorithm", "median"],
        );
        let mut scales = vec![0.0f32; shape.dim];
        let m1 = bencher.measure("naive(strided)", || {
            quant::scales::compute_scales_naive(&wl.k, &mut scales)
        });
        let m2 = bencher.measure("rowsweep", || {
            quant::scales::compute_scales_rowsweep(&wl.k, &mut scales)
        });
        t3.row(&["naive (paper Listing 2, strided)".into(), cell_time(m1.median())]);
        t3.row(&["row-sweep (cache-friendly)".into(), cell_time(m2.median())]);
        report.add("a3_scales_algo", "naive_strided", Some(m1.median()), &[]);
        report.add("a3_scales_algo", "rowsweep", Some(m2.median()), &[]);
        for &threads in &sweep {
            let m = bencher.measure("parallel", || {
                quant::scales::compute_scales_parallel(&wl.k, &mut scales, threads)
            });
            t3.row(&[format!("row-sweep x{threads} threads"), cell_time(m.median())]);
            report.add(
                "a3_scales_algo",
                "rowsweep_parallel",
                Some(m.median()),
                &[("threads", Json::Num(threads as f64))],
            );
        }
        kvq::bench::figures::emit(&t3, "ablation_a3_scales_algo");
    }

    // A4: CPU quantize variants + the parallel variant per thread count.
    {
        let scales = quant::compute_scales(&wl.k);
        let mut out = Int8Matrix::zeros(wl.k.rows, wl.k.cols);
        let mut t4 = Table::new(
            &format!("A4 — CPU quantize variants on {}", shape.tag()),
            &["variant", "median", "vs naive"],
        );
        let base = bencher
            .measure("naive", || {
                quant::quantize::quantize_variant(Variant::Naive, &wl.k, &scales, &mut out)
            })
            .median();
        for v in Variant::ALL {
            let m = bencher.measure(v.name(), || {
                quant::quantize::quantize_variant(v, &wl.k, &scales, &mut out)
            });
            t4.row(&[
                v.name().into(),
                cell_time(m.median()),
                format!("{:.2}x", base / m.median()),
            ]);
            report.add("a4_quantize_variants", v.name(), Some(m.median()), &[]);
        }
        for &threads in &sweep {
            let mp = bencher.measure("parallel", || {
                quant::quantize_parallel(&wl.k, &scales, &mut out, threads)
            });
            t4.row([
                format!("vectorized x{threads} threads"),
                cell_time(mp.median()),
                format!("{:.2}x", base / mp.median()),
            ]
            .as_ref());
            report.add(
                "a4_quantize_variants",
                "vectorized_parallel",
                Some(mp.median()),
                &[("threads", Json::Num(threads as f64))],
            );
        }
        kvq::bench::figures::emit(&t4, "ablation_a4_cpu_variants");
    }

    // A8: dequantize — serial vs the shared parallel runtime.
    {
        let q = quant::quantize_fused(&wl.k);
        let mut rec = Fp32Matrix::zeros(q.rows, q.cols);
        let mut t8 = Table::new(
            &format!("A8 — dequantize serial vs parallel on {}", shape.tag()),
            &["path", "median"],
        );
        let ms = bencher.measure("serial", || quant::dequantize_into(&q, &mut rec));
        t8.row(&["serial".into(), cell_time(ms.median())]);
        report.add("a8_dequantize", "serial", Some(ms.median()), &[]);
        for &threads in &sweep {
            let m = bencher.measure("parallel", || {
                quant::dequantize_parallel(&q, &mut rec, threads)
            });
            t8.row(&[format!("parallel x{threads} threads"), cell_time(m.median())]);
            report.add(
                "a8_dequantize",
                "parallel",
                Some(m.median()),
                &[("threads", Json::Num(threads as f64))],
            );
        }
        kvq::bench::figures::emit(&t8, "ablation_a8_dequantize_parallel");
    }

    // A9: fused INT8 attention kernels — the zero-copy decode hot loop.
    // Score pass (q·K̂ over T rows) and softmax·V accumulation, fused
    // dequantization vs the dequantize-into-staging-then-dot baseline.
    // Runs in --smoke so BENCH_smoke.json carries the kernel sweep.
    {
        let (t, d) = if smoke { (512, 64) } else { (4096, 128) };
        let kmat = Fp32Matrix::random_normal(t, d, 1.0, 0xA9);
        let q8 = quant::quantize_fused(&kmat);
        let mut qrow = vec![0.0f32; d];
        let mut w = vec![0.0f32; t];
        {
            let mut rng = kvq::util::rng::Rng::new(0x4A9);
            rng.fill_uniform(&mut qrow, -1.0, 1.0);
            rng.fill_uniform(&mut w, 0.0, 1.0 / t as f32);
        }
        let mut scores = vec![0.0f32; t];
        let mut acc = vec![0.0f32; d];
        let mut t9 = Table::new(
            &format!("A9 — fused INT8 attention over {t}x{d} (score pass + softmax·V)"),
            &["kernel", "score median", "accumulate median"],
        );
        // Baseline: materialize the dequantized copy, then attend on f32
        // (what the staged decode path pays per token).
        let mut staging = Fp32Matrix::zeros(t, d);
        let mb = bencher.measure("dequant_then_dot", || {
            quant::dequantize_into(&q8, &mut staging);
            quant::attn::dot_rows_f32(&qrow, &staging.data, &mut scores);
        });
        let mba = bencher.measure("dequant_then_accumulate", || {
            quant::dequantize_into(&q8, &mut staging);
            acc.fill(0.0);
            quant::attn::accumulate_rows_f32(&w, &staging.data, &mut acc);
        });
        t9.row(&[
            "dequantize-then-dot (staged)".into(),
            cell_time(mb.median()),
            cell_time(mba.median()),
        ]);
        report.add(
            "a9_fused_attention",
            "dequant_then_dot",
            Some(mb.median()),
            &[("accumulate_median_s", Json::Num(mba.median()))],
        );
        for v in Variant::ALL {
            let ms = bencher.measure(v.name(), || {
                quant::attn::dot_rows_i8(v, &qrow, &q8.data, &q8.scales, &mut scores);
            });
            let ma = bencher.measure(v.name(), || {
                acc.fill(0.0);
                quant::attn::accumulate_rows_i8(v, &w, &q8.data, &q8.scales, &mut acc);
            });
            t9.row(&[
                format!("fused {}", v.name()),
                cell_time(ms.median()),
                cell_time(ma.median()),
            ]);
            report.add(
                "a9_fused_attention",
                v.name(),
                Some(ms.median()),
                &[("accumulate_median_s", Json::Num(ma.median()))],
            );
        }
        kvq::bench::figures::emit(&t9, "ablation_a9_fused_attention");
    }

    // A10: kernel backend — runtime-dispatched SIMD vs the four scalar
    // variants on the fused INT8 dot and softmax·V accumulation. The
    // scalar rows dispatch through the same layer with Isa::Scalar, so
    // the contrast isolates the backend, not the call path.
    {
        use kvq::quant::simd::{self, Isa, KernelBackend};
        let simd_isa = KernelBackend::Simd.resolve();
        report.env("kernel_isa", simd_isa.name().into());
        let mut t10 = Table::new(
            "A10 — kernel_backend: scalar variants vs runtime-dispatched SIMD (fused INT8)",
            &["d", "kernel", "score median", "accumulate median", "vs scalar vectorized"],
        );
        for d in [64usize, 128, 4096] {
            let rows = match (d, smoke) {
                (4096, true) => 64,
                (4096, false) => 256,
                (_, true) => 512,
                (_, false) => 2048,
            };
            let kmat = Fp32Matrix::random_normal(rows, d, 1.0, 0xA10 ^ d as u64);
            let q8 = quant::quantize_fused(&kmat);
            let mut qrow = vec![0.0f32; d];
            let mut w = vec![0.0f32; rows];
            {
                let mut rng = kvq::util::rng::Rng::new(0x10A ^ d as u64);
                rng.fill_uniform(&mut qrow, -1.0, 1.0);
                rng.fill_uniform(&mut w, 0.0, 1.0 / rows as f32);
            }
            let mut scores = vec![0.0f32; rows];
            let mut acc = vec![0.0f32; d];
            let mut base_vectorized = 0.0f64;
            for v in Variant::ALL {
                let ms = bencher.measure(v.name(), || {
                    simd::dot_rows_i8(
                        Isa::Scalar,
                        v,
                        &qrow,
                        &q8.data,
                        &q8.scales,
                        &mut scores,
                    );
                });
                let ma = bencher.measure(v.name(), || {
                    acc.fill(0.0);
                    simd::accumulate_rows_i8(
                        Isa::Scalar,
                        v,
                        &w,
                        &q8.data,
                        &q8.scales,
                        &mut acc,
                    );
                });
                if v == Variant::Vectorized {
                    base_vectorized = ms.median();
                }
                t10.row(&[
                    d.to_string(),
                    format!("scalar {}", v.name()),
                    cell_time(ms.median()),
                    cell_time(ma.median()),
                    "-".into(),
                ]);
                report.add(
                    "a10_kernel_backend",
                    &format!("scalar_{}", v.name()),
                    Some(ms.median()),
                    &[
                        ("d", Json::Num(d as f64)),
                        ("rows", Json::Num(rows as f64)),
                        ("accumulate_median_s", Json::Num(ma.median())),
                    ],
                );
            }
            let ms = bencher.measure("simd", || {
                simd::dot_rows_i8(
                    simd_isa,
                    Variant::Vectorized,
                    &qrow,
                    &q8.data,
                    &q8.scales,
                    &mut scores,
                );
            });
            let ma = bencher.measure("simd", || {
                acc.fill(0.0);
                simd::accumulate_rows_i8(
                    simd_isa,
                    Variant::Vectorized,
                    &w,
                    &q8.data,
                    &q8.scales,
                    &mut acc,
                );
            });
            t10.row(&[
                d.to_string(),
                format!("simd ({})", simd_isa.name()),
                cell_time(ms.median()),
                cell_time(ma.median()),
                format!("{:.2}x", base_vectorized / ms.median()),
            ]);
            report.add(
                "a10_kernel_backend",
                "simd",
                Some(ms.median()),
                &[
                    ("d", Json::Num(d as f64)),
                    ("rows", Json::Num(rows as f64)),
                    ("isa", simd_isa.name().into()),
                    ("accumulate_median_s", Json::Num(ma.median())),
                    (
                        "speedup_vs_scalar_vectorized",
                        Json::Num(base_vectorized / ms.median()),
                    ),
                ],
            );
        }
        kvq::bench::figures::emit(&t10, "ablation_a10_kernel_backend");
    }

    // A11: decode_batching — the fused multi-query batched decode path
    // (wave_view + *_rows_mq kernels) vs W independent per-sequence
    // decode_paged calls on the same cache. Waves are built the way the
    // engine builds them: shared COW-prefix blocks come from fork(), so
    // the batched path dequantizes each shared physical block once per
    // (wave, layer, head) while the per-sequence path pays once per
    // member. Outputs are bit-identical; only the traversal is measured.
    {
        use kvq::kvcache::manager::{CacheConfig, KvCacheManager};
        use kvq::kvcache::{Precision, QuantPolicy};
        use kvq::model::weights::Weights;
        use kvq::model::{BatchScratch, CpuModel, ModelSpec};
        use kvq::quant::simd::KernelBackend;

        let spec = ModelSpec::test_tiny();
        let mdl = CpuModel::new(spec.clone(), Weights::synthetic(&spec, 0xA11));
        let isa = KernelBackend::Auto.resolve();
        let cache_cfg = CacheConfig {
            layers: spec.layers,
            heads: spec.heads,
            head_dim: spec.head_dim,
            max_seq: spec.max_seq,
            block_size: 4,
            num_blocks: 4096,
            scale_margin: 1.0,
        };
        let ctx = 16usize; // decode position; shared_len must stay block-aligned
        let mut rng = kvq::util::rng::Rng::new(0x11A);
        let tokens: Vec<i32> = (0..ctx + 1).map(|_| rng.below(spec.vocab as u64) as i32).collect();
        let mut t11 = Table::new(
            "A11 — decode_batching: fused multi-query wave vs per-sequence decode (INT8)",
            &["width", "shared", "unbatched", "batched", "speedup", "deduped", "bytes saved"],
        );
        for width in [1usize, 4, 16] {
            for shared_frac in [0.0f64, 0.5, 1.0] {
                let shared_len = (ctx as f64 * shared_frac) as usize;
                let mut mgr = KvCacheManager::new(
                    cache_cfg,
                    QuantPolicy::uniform(Precision::Int8, cache_cfg.layers, cache_cfg.heads),
                );
                // Shared prefix via fork (COW blocks), per-member tail via
                // append; shared_frac 0 prefills each member independently.
                let ids: Vec<_> = if shared_len == 0 {
                    let pre = mdl.prefill(&tokens, ctx);
                    (0..width)
                        .map(|_| {
                            let id = mgr.new_sequence();
                            mgr.set_prefill(id, &pre.k, &pre.v, ctx).unwrap();
                            id
                        })
                        .collect()
                } else {
                    let pre = mdl.prefill(&tokens, shared_len);
                    let parent = mgr.new_sequence();
                    mgr.set_prefill(parent, &pre.k, &pre.v, shared_len).unwrap();
                    let ids: Vec<_> = (0..width).map(|_| mgr.fork(parent).unwrap()).collect();
                    mgr.free(parent);
                    for &id in &ids {
                        for pos in shared_len..ctx {
                            let (_, kn, vn) = {
                                let view = mgr.view(id).unwrap();
                                mdl.decode_paged(tokens[pos], pos, &view, Variant::Vectorized, isa)
                                    .unwrap()
                            };
                            mgr.append_row(id, &kn, &vn).unwrap();
                        }
                    }
                    ids
                };
                let queries: Vec<(i32, usize)> = ids.iter().map(|_| (tokens[ctx], ctx)).collect();
                let mu = bencher.measure("unbatched", || {
                    for (&id, &(tok, pos)) in ids.iter().zip(&queries) {
                        let view = mgr.view(id).unwrap();
                        mdl.decode_paged(tok, pos, &view, Variant::Vectorized, isa).unwrap();
                    }
                });
                let mut scratch = BatchScratch::new();
                let mb = bencher.measure("batched", || {
                    let wave = mgr.wave_view(&ids).unwrap();
                    mdl.decode_paged_batch(&queries, &wave, Variant::Vectorized, isa, &mut scratch)
                        .unwrap();
                });
                let wave = mgr.wave_view(&ids).unwrap();
                let deduped = wave.blocks_deduped();
                let batched_bytes = wave.attention_bytes();
                let unbatched_bytes: usize =
                    ids.iter().map(|&id| mgr.view(id).unwrap().attention_bytes()).sum();
                let speedup = mu.median() / mb.median();
                t11.row(&[
                    width.to_string(),
                    format!("{shared_frac:.1}"),
                    cell_time(mu.median()),
                    cell_time(mb.median()),
                    format!("{speedup:.2}x"),
                    deduped.to_string(),
                    (unbatched_bytes - batched_bytes).to_string(),
                ]);
                report.add(
                    "a11_decode_batching",
                    &format!("w{width}_shared{}", (shared_frac * 100.0) as usize),
                    Some(mb.median()),
                    &[
                        ("width", Json::Num(width as f64)),
                        ("shared_frac", Json::Num(shared_frac)),
                        ("unbatched_median_s", Json::Num(mu.median())),
                        ("speedup_vs_unbatched", Json::Num(speedup)),
                        ("blocks_deduped", Json::Num(deduped as f64)),
                        ("cache_bytes_batched", Json::Num(batched_bytes as f64)),
                        ("cache_bytes_unbatched", Json::Num(unbatched_bytes as f64)),
                    ],
                );
                for id in ids {
                    mgr.free(id);
                }
            }
        }
        kvq::bench::figures::emit(&t11, "ablation_a11_decode_batching");
    }

    // A12: scale granularity — one grid frozen over the whole prompt vs
    // per-block grids. The per-block encode freezes an eq.-6 grid over
    // each block's own rows; the generated span clamps into the last
    // block's grid either way (frozen-scale serving: decode rows never
    // refreeze). Keys drift in magnitude across the sequence, the regime
    // where a whole-prompt grid over-ranges the early blocks.
    {
        let (t_rows, d, bs) = if smoke { (256usize, 64usize, 32usize) } else { (2048, 128, 64) };
        let prompt_rows = t_rows / 2;
        let mut k = Fp32Matrix::random_normal(t_rows, d, 1.0, 0xA12);
        for t in 0..t_rows {
            let g = 0.25 + 1.75 * t as f32 / t_rows as f32;
            for c in 0..d {
                k.data[t * d + c] *= g;
            }
        }
        let slice = |lo: usize, hi: usize| {
            Fp32Matrix::from_vec(hi - lo, d, k.data[lo * d..hi * d].to_vec())
        };
        // Encode the sequence through grids frozen per `grain` prompt
        // rows (grain == prompt_rows is the pre-refactor policy; grain ==
        // block_size is the paged cache's), writing the dequantized
        // reconstruction into `out`.
        let encode = |grain: usize, out: &mut Fp32Matrix| {
            let mut grid = vec![0.0f32; d];
            let mut at = 0usize;
            while at < prompt_rows {
                let hi = (at + grain).min(prompt_rows);
                let seg = slice(at, hi);
                quant::scales::compute_scales_rowsweep(&seg, &mut grid);
                let mut q = Int8Matrix::zeros(seg.rows, d);
                quant::quantize::quantize_vectorized(&seg, &grid, &mut q);
                out.data[at * d..hi * d].copy_from_slice(&quant::dequantize(&q).data);
                at = hi;
            }
            let seg = slice(prompt_rows, t_rows);
            let mut q = Int8Matrix::zeros(seg.rows, d);
            quant::quantize::quantize_vectorized(&seg, &grid, &mut q);
            out.data[prompt_rows * d..].copy_from_slice(&quant::dequantize(&q).data);
        };
        let queries = Fp32Matrix::random_normal(32, d, 1.0, 0x12A);
        let mut probs = Fp32Matrix::random_uniform(32, t_rows, 0.0, 1.0, 0x12B);
        for r in 0..probs.rows {
            let row = &mut probs.data[r * t_rows..(r + 1) * t_rows];
            let sum: f32 = row.iter().sum();
            row.iter_mut().for_each(|w| *w /= sum);
        }
        let mut t12 = Table::new(
            &format!(
                "A12 — scale granularity over {t_rows}x{d} (prompt {prompt_rows}, block {bs})"
            ),
            &["granularity", "encode median", "key max_abs_err", "key l2_err", "attn_err",
              "value_out_err"],
        );
        for (name, grain) in [("per_prompt", prompt_rows), ("per_block", bs)] {
            let mut rec = Fp32Matrix::zeros(t_rows, d);
            let m = bencher.measure(name, || encode(grain, &mut rec));
            let (max_err, l2) = (quant::max_abs_error(&k, &rec), quant::l2_error(&k, &rec));
            let attn = quant::attention_score_error(&queries, &k, &rec);
            let vout = quant::value_output_error(&probs, &k, &rec);
            t12.row(&[
                name.into(),
                cell_time(m.median()),
                cell_f(max_err, 5),
                cell_f(l2, 3),
                cell_f(attn, 5),
                cell_f(vout, 5),
            ]);
            report.add(
                "a12_scale_granularity",
                name,
                Some(m.median()),
                &[
                    ("grain_rows", Json::Num(grain as f64)),
                    ("key_max_abs_err", Json::Num(max_err)),
                    ("key_l2_err", Json::Num(l2)),
                    ("attn_err", Json::Num(attn)),
                    ("value_out_err", Json::Num(vout)),
                ],
            );
        }
        kvq::bench::figures::emit(&t12, "ablation_a12_scale_granularity");
    }

    // A13: tiered KV cache — hot-pool fraction × cold tier off/on on the
    // serving engine (CPU oracle backend, so it runs in --smoke). Three
    // deterministic phases per cell: warm two prompts into the prefix
    // trie (sequential), pressure-burst four fresh prompts concurrently
    // on a constrained pool (forces demotion with the tier on, eviction
    // with it off), then repeat the warm prompts (promotions with the
    // tier on). k8v4 keeps V streams at half the K width, so the cell
    // also checks the sub-pool acceptance bar: physical pool footprint
    // strictly below a single pool padded to the widest stream.
    {
        use kvq::coordinator::batcher::BatcherConfig;
        use kvq::coordinator::engine::{self, EngineConfig};
        use kvq::coordinator::request::collect_response;
        use kvq::coordinator::router::{RoutePolicy, Router};
        use kvq::kvcache::PolicySpec;
        use kvq::model::runner::CpuBackend;
        use kvq::model::sample::SamplingParams;
        use kvq::model::weights::Weights;
        use kvq::model::ModelSpec;

        let spec = ModelSpec::test_tiny();
        let resolved = PolicySpec::K8V4.resolve(spec.layers, spec.heads, spec.head_dim)?;
        let padded_block_bytes = resolved.max_block_bytes(spec.block_size, spec.head_dim);
        let prompt_len = 2 * spec.block_size; // 2 blocks per stream
        let max_new = spec.block_size; // +1 block per stream of decode growth
        let blocks_per_seq = 2 * spec.layers * (prompt_len + max_new).div_ceil(spec.block_size);
        let base_blocks = blocks_per_seq * 8; // room for every sequence at once
        let vocab = spec.vocab;
        let prompt = |tag: usize| -> Vec<i32> {
            (0..prompt_len).map(|j| ((tag * 7 + j * 3 + 5) % vocab) as i32).collect()
        };
        let warm: Vec<Vec<i32>> = vec![prompt(1), prompt(2)];
        let fresh: Vec<Vec<i32>> = (3..7).map(prompt).collect();

        let run_cell = |num_blocks: usize, cold_blocks: usize| {
            let ecfg = EngineConfig {
                quant_policy: PolicySpec::K8V4,
                num_blocks: Some(num_blocks),
                prefix_cache_blocks: 64,
                cold_tier_blocks: Some(cold_blocks),
                prefetch_depth: 2,
                batcher: BatcherConfig { max_prefills_per_step: 4, ..Default::default() },
                ..Default::default()
            };
            let (h, join) = engine::spawn(ecfg, || {
                let spec = ModelSpec::test_tiny();
                let w = Weights::synthetic(&spec, 7);
                Ok(Box::new(CpuBackend::new(spec, w)) as Box<dyn kvq::model::LmBackend>)
            });
            let mut router = Router::new(RoutePolicy::RoundRobin);
            router.add_engine("tier", h.clone());
            let mut outputs: Vec<Vec<i32>> = Vec::new();
            // Phase 1 (warm): sequential, populates the prefix trie.
            for p in &warm {
                let (_, rx) =
                    router.submit(p.clone(), max_new, SamplingParams::default()).unwrap();
                outputs.push(collect_response(&rx).0);
            }
            // Phase 2 (pressure): concurrent burst of fresh prompts.
            let streams: Vec<_> = fresh
                .iter()
                .map(|p| {
                    router.submit(p.clone(), max_new, SamplingParams::default()).unwrap().1
                })
                .collect();
            for rx in &streams {
                outputs.push(collect_response(rx).0);
            }
            // Phase 3 (repeat): the warm prompts again — promotions when
            // the cold tier holds what phase 2 demoted.
            for p in &warm {
                let (_, rx) =
                    router.submit(p.clone(), max_new, SamplingParams::default()).unwrap();
                outputs.push(collect_response(&rx).0);
            }
            h.drain();
            join.join().ok();
            (outputs, h.metrics.snapshot())
        };

        let mut t13 = Table::new(
            "A13 — tier_sweep: hot-pool fraction x cold tier (k8v4, warm/burst/repeat)",
            &["hot", "cold", "preempt", "avoided", "demote", "promote", "ratio", "p50"],
        );
        let mut reference: Option<Vec<Vec<i32>>> = None;
        for frac in [1.0f64, 0.5, 0.25] {
            let num_blocks = (base_blocks as f64 * frac) as usize;
            for cold_on in [false, true] {
                let cold_blocks = if cold_on { num_blocks } else { 0 };
                let (outputs, snap) = run_cell(num_blocks, cold_blocks);
                match &reference {
                    None => reference = Some(outputs),
                    Some(expect) => assert_eq!(
                        &outputs,
                        expect,
                        "tier cell hot={frac} cold={cold_on} must be byte-identical \
                         to the unconstrained run"
                    ),
                }
                assert!(
                    (snap.pool_physical_bytes as usize) < padded_block_bytes * num_blocks,
                    "k8v4 sub-pools must sit strictly below the padded widest-stream \
                     pool ({} vs {})",
                    snap.pool_physical_bytes,
                    padded_block_bytes * num_blocks
                );
                if cold_on && frac < 1.0 {
                    assert!(
                        snap.tier.preemptions_avoided > 0,
                        "constrained pool with the tier on must absorb reclaims by \
                         demotion (hot frac {frac})"
                    );
                    assert!(snap.tier.promotions > 0, "repeats must promote from cold");
                }
                let label = format!(
                    "hot{}_{}",
                    (frac * 100.0) as usize,
                    if cold_on { "on" } else { "off" }
                );
                let promote_latency = if snap.tier.promotions > 0 {
                    snap.tier.promote_secs / snap.tier.promotions as f64
                } else {
                    0.0
                };
                t13.row(&[
                    format!("{frac:.2}"),
                    if cold_on { "on" } else { "off" }.into(),
                    snap.preemptions.to_string(),
                    snap.tier.preemptions_avoided.to_string(),
                    snap.tier.demotions.to_string(),
                    snap.tier.promotions.to_string(),
                    format!("{:.2}x", snap.tier.compression_ratio()),
                    cell_time(promote_latency),
                ]);
                report.add(
                    "a13_tier_sweep",
                    &label,
                    None,
                    &[
                        ("hot_pool_fraction", Json::Num(frac)),
                        ("pool_blocks", Json::Num(num_blocks as f64)),
                        ("cold_tier_blocks", Json::Num(cold_blocks as f64)),
                        ("preemptions", Json::Num(snap.preemptions as f64)),
                        ("preemptions_avoided", Json::Num(snap.tier.preemptions_avoided as f64)),
                        ("demotions", Json::Num(snap.tier.demotions as f64)),
                        ("promotions", Json::Num(snap.tier.promotions as f64)),
                        ("prefetch_hits", Json::Num(snap.tier.prefetch_hits as f64)),
                        ("prefetch_misses", Json::Num(snap.tier.prefetch_misses as f64)),
                        ("compression_ratio", Json::Num(snap.tier.compression_ratio())),
                        ("promote_latency_s", Json::Num(promote_latency)),
                        ("pool_physical_bytes", Json::Num(snap.pool_physical_bytes as f64)),
                        ("padded_pool_bytes", Json::Num((padded_block_bytes * num_blocks) as f64)),
                        ("prefix_saved_tokens", Json::Num(snap.prefix_saved_tokens as f64)),
                    ],
                );
            }
        }
        println!(
            "[a13_tier_sweep] tokens identical across all cells ✓  (k8v4 physical pool \
             strictly below padded baseline)"
        );
        kvq::bench::figures::emit(&t13, "ablation_a13_tier_sweep");
    }

    // A5 + A7 need the runtime.
    let dir = kvq::runtime::default_artifact_dir();
    if smoke {
        // Smoke keeps CI cheap: skip artifact-dependent sections.
    } else if std::path::Path::new(&dir).join("manifest.json").exists() {
        let rt = Rc::new(Runtime::new(&dir)?);

        // A5: Pallas-scheduled vectorized kernel vs XLA's own fusion of
        // the jnp reference.
        {
            let scales = quant::compute_scales(&wl.k);
            let kbuf = rt.stage_f32(&wl.k.data, &[shape.tokens, shape.dim])?;
            let sbuf = rt.stage_f32(&scales, &[shape.dim])?;
            let pallas = rt.load(&format!("quantize_vectorized_{}", shape.tag()))?;
            let fused = rt.load(&format!("quantize_fused_{}", shape.tag()))?;
            let xla_ref = rt.load(&format!("quantize_ref_{}", shape.tag()))?;
            let mut t5 = Table::new(
                &format!("A5 — Pallas schedule vs plain XLA codegen on {}", shape.tag()),
                &["kernel", "median"],
            );
            let mp = bencher.measure("pallas", || {
                pallas.run_b(&[&kbuf, &sbuf]).unwrap();
            });
            let mf = bencher.measure("pallas_fused", || {
                fused.run_b(&[&kbuf]).unwrap();
            });
            let mr = bencher.measure("xla_ref", || {
                xla_ref.run_b(&[&kbuf]).unwrap();
            });
            t5.row(&["pallas vectorized (scales given)".into(), cell_time(mp.median())]);
            t5.row(&["pallas fused (scales+quant, 1 pass)".into(), cell_time(mf.median())]);
            t5.row(&["plain-XLA jnp reference (scales+quant)".into(), cell_time(mr.median())]);
            report.add("a5_pallas_vs_xla", "pallas_vectorized", Some(mp.median()), &[]);
            report.add("a5_pallas_vs_xla", "pallas_fused", Some(mf.median()), &[]);
            report.add("a5_pallas_vs_xla", "xla_ref", Some(mr.median()), &[]);
            kvq::bench::figures::emit(&t5, "ablation_a5_pallas_vs_xla");
        }

        // A7: host-side row quantization vs PJRT round-trip for one row.
        {
            let d = 1024usize;
            let row = Fp32Matrix::random_uniform(1, d, -1.0, 1.0, 7);
            let scales = quant::compute_scales(&row);
            let mut out_row = vec![0i8; d];
            let mh = bencher.measure("host row", || {
                quant::quantize_row_into(&row.data, &scales, &mut out_row);
            });
            // Closest artifact: the smallest quantize at 2048x128 is still
            // ~256k elements; time the *call overhead* by running it on a
            // staged buffer — the point is dispatch cost vs nanoseconds on
            // host.
            let small_shape = &reg.ci[0];
            let wl2 = Workload::uniform(small_shape, 3);
            let s2 = quant::compute_scales(&wl2.k);
            let kb = rt.stage_f32(&wl2.k.data, &[small_shape.tokens, small_shape.dim])?;
            let sb = rt.stage_f32(&s2, &[small_shape.dim])?;
            let exe = rt.load(&format!("quantize_vectorized_{}", small_shape.tag()))?;
            let md = bencher.measure("pjrt dispatch", || {
                exe.run_b(&[&kb, &sb]).unwrap();
            });
            let mut t7 = Table::new(
                "A7 — cache-writer placement: host row quantize vs PJRT dispatch",
                &["path", "median", "note"],
            );
            t7.row(&[
                format!("host quantize_row_into (D={d})"),
                cell_time(mh.median()),
                "engine hot path".into(),
            ]);
            t7.row(&[
                format!("PJRT execute ({} elems)", small_shape.elements()),
                cell_time(md.median()),
                "includes dispatch+readback".into(),
            ]);
            report.add("a7_writer_placement", "host_row", Some(mh.median()), &[]);
            report.add("a7_writer_placement", "pjrt_dispatch", Some(md.median()), &[]);
            kvq::bench::figures::emit(&t7, "ablation_a7_writer_placement");
        }
    } else {
        println!("[ablations] artifacts missing; skipping A5/A7 (run `make artifacts`)");
    }

    // A6: INT4 vs INT8.
    {
        let (rows, cols) = if smoke { (512, 64) } else { (4096, 256) };
        let k = Fp32Matrix::random_uniform(rows, cols, -1.0, 1.0, 0xA6);
        let q8 = quant::quantize_fused(&k);
        let q4 = quant::int4::quantize4(&k);
        let r8 = quant::dequantize(&q8);
        let r4 = quant::int4::dequantize4(&q4);
        let mut t6 = Table::new(
            "A6 — INT8 vs INT4 (paper §8.1 extension)",
            &["format", "max_abs_err", "l2_err", "payload ratio vs fp32"],
        );
        for (name, err_rec, ratio) in [
            ("int8", &r8, q8.compression_ratio()),
            ("int4", &r4, q4.compression_ratio()),
        ] {
            t6.row(&[
                name.into(),
                cell_f(quant::max_abs_error(&k, err_rec), 5),
                cell_f(quant::l2_error(&k, err_rec), 3),
                format!("{ratio:.2}x"),
            ]);
            report.add(
                "a6_int4",
                name,
                None,
                &[
                    ("max_abs_err", Json::Num(quant::max_abs_error(&k, err_rec))),
                    ("l2_err", Json::Num(quant::l2_error(&k, err_rec))),
                    ("compression_ratio", Json::Num(ratio)),
                ],
            );
        }
        kvq::bench::figures::emit(&t6, "ablation_a6_int4");
    }

    let path = report.write()?;
    println!("[json] {path}");
    Ok(())
}
