//! Table 1 — the KV-cache memory model (and the paper's Table-3 workload
//! registry for reference).

use kvq::bench::figures;
use kvq::config::shapes::ShapeRegistry;
use kvq::util::harness::Table;

fn main() -> anyhow::Result<()> {
    figures::emit(&figures::table1(), "table1_memory");
    // Policy sweep: per-policy compression on the same geometry (k8v4
    // lands between uniform int8 and int4; sink8 just under int8).
    figures::emit(&figures::table1_policies(), "table1_policies");

    // Table 3: the benchmark configurations (paper set).
    let reg = ShapeRegistry::load_default()?;
    let mut t = Table::new(
        "Table 3 — Test configurations",
        &["name", "tokens (T)", "head dim (D)", "elements", "description"],
    );
    for s in &reg.paper {
        t.row(&[
            s.name.clone(),
            s.tokens.to_string(),
            s.dim.to_string(),
            s.elements().to_string(),
            s.desc.clone(),
        ]);
    }
    figures::emit(&t, "table3_configs");
    Ok(())
}
