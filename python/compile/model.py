"""L2: JAX transformer with an INT8-quantized KV cache.

This is the compute graph the Rust coordinator serves. Two entry points are
AOT-lowered per model config (see aot.py):

* ``prefill``     — full-sequence forward over a padded prompt. Emits the
  next-token logits at the last valid position plus the FP32 K/V tensors for
  every layer; the Rust side quantizes them (per-channel, per head) into its
  paged INT8 cache, freezing one eq.-6 grid **per block** over that block's
  own rows.
* ``decode_step`` — single-token forward over the quantized cache. Attention
  runs over the INT8 history (dequantize-in-graph — never materializing an
  FP32 cache in HBM), which is the integration the paper's future-work
  section calls for; scales arrive as ``(L, H, B, d)`` per-block grids
  (``B = ceil(max_seq / block_size)``) and row ``t`` dequantizes through
  block ``t // block_size``'s grid — the exact layout the Rust runner
  stages (rust/src/model/runner.rs). A ``decode_step_pallas`` variant
  routes the history attention through the fused Pallas dequant-attention
  kernel. Both emit next-token logits and the new token's FP32 K/V rows
  for the Rust side to quantize and append.

Weights are *runtime inputs* (the Rust side generates seeded synthetic
weights with the same layout — see rust/src/model/weights.rs and the
param manifest emitted by aot.py). Architecture: pre-RMSNorm GPT with tied
embedding/LM-head, GELU MLP, rotary positions, byte-level vocab.
"""

from __future__ import annotations

import dataclasses
from typing import List

import jax
import jax.numpy as jnp

from .kernels import quant as kernels
from .kernels import ref


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Architecture hyper-parameters. Mirrors configs/bench_shapes.json."""

    name: str
    vocab: int
    layers: int
    heads: int
    head_dim: int
    d_ff: int
    max_seq: int
    block_size: int = 16

    @property
    def d_model(self) -> int:
        return self.heads * self.head_dim

    def param_specs(self) -> List[tuple]:
        """(name, shape) for every parameter, in the flat argument order
        shared with the Rust weight generator. Keep this list append-only —
        it is the ABI between L2 and L3."""
        m, f = self.d_model, self.d_ff
        specs = [("embedding", (self.vocab, m))]
        for i in range(self.layers):
            specs += [
                (f"l{i}.ln1", (m,)),
                (f"l{i}.wq", (m, m)),
                (f"l{i}.wk", (m, m)),
                (f"l{i}.wv", (m, m)),
                (f"l{i}.wo", (m, m)),
                (f"l{i}.ln2", (m,)),
                (f"l{i}.w1", (m, f)),
                (f"l{i}.w2", (f, m)),
            ]
        specs.append(("ln_f", (m,)))
        return specs

    def unflatten(self, flat):
        """Group the flat param list into (embedding, per-layer dicts, ln_f)."""
        names = [n for n, _ in self.param_specs()]
        params = dict(zip(names, flat))
        layers = []
        for i in range(self.layers):
            layers.append({k: params[f"l{i}.{k}"] for k in
                           ("ln1", "wq", "wk", "wv", "wo", "ln2", "w1", "w2")})
        return params["embedding"], layers, params["ln_f"]


def rmsnorm(x, w, eps=1e-5):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def gelu(x):
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608 * (x + 0.044715 * x**3)))


def _split_heads(x, heads, head_dim):
    # (T, M) -> (H, T, d)
    t = x.shape[0]
    return x.reshape(t, heads, head_dim).transpose(1, 0, 2)


def _merge_heads(x):
    # (H, T, d) -> (T, M)
    h, t, d = x.shape
    return x.transpose(1, 0, 2).reshape(t, h * d)


def _rope(x, positions):
    """Rotary position embedding over the head dimension.

    x: (H, T, d); positions: (T,) int32. Standard theta=10000 pairing of
    low/high halves — cheap, and keeps K statistics roughly stationary per
    channel, which is what makes frozen-scale INT8 decode viable
    (DESIGN.md §Hardware-Adaptation)."""
    h, t, d = x.shape
    half = d // 2
    freqs = 10000.0 ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # (T, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def prefill(spec: ModelSpec, flat_params, tokens, length):
    """Padded-prompt forward pass.

    tokens: (S,) int32 padded to spec.max_seq; length: () int32 valid count.
    Returns (logits_last (V,), k_cache (L, H, S, d) f32, v_cache idem).
    """
    emb, layers, ln_f = spec.unflatten(flat_params)
    s = tokens.shape[0]
    h, d = spec.heads, spec.head_dim
    positions = jnp.arange(s, dtype=jnp.int32)
    x = emb[tokens]  # (S, M)

    valid = positions[None, :] < length  # (1, S)
    causal = positions[None, :] <= positions[:, None]  # (S, S)
    mask = causal & valid  # (S, S)

    ks, vs = [], []
    for lp in layers:
        xn = rmsnorm(x, lp["ln1"])
        q = _rope(_split_heads(xn @ lp["wq"], h, d), positions)
        k = _rope(_split_heads(xn @ lp["wk"], h, d), positions)
        v = _split_heads(xn @ lp["wv"], h, d)
        ks.append(k)
        vs.append(v)
        scores = jnp.einsum("hqd,hkd->hqk", q, k) / jnp.sqrt(jnp.float32(d))
        scores = jnp.where(mask[None, :, :], scores, -1e30)
        w = ref.softmax(scores)
        attn = jnp.einsum("hqk,hkd->hqd", w, v)
        x = x + _merge_heads(attn) @ lp["wo"]
        xn = rmsnorm(x, lp["ln2"])
        x = x + gelu(xn @ lp["w1"]) @ lp["w2"]

    x = rmsnorm(x, ln_f)
    last = jnp.take(x, length - 1, axis=0)  # (M,)
    logits = last @ emb.T  # tied LM head, (V,)
    k_cache = jnp.stack(ks)  # (L, H, S, d)
    v_cache = jnp.stack(vs)
    return logits, k_cache, v_cache


def _attended_history(q, kq, k_scales, vq, v_scales, length, block_size):
    """Masked attention over the quantized history, returning the pieces
    needed for a streaming-softmax merge with the current token.

    q: (H, d); kq/vq: (H, S, d) int8; scales (H, B, d) per-block grids
    (row t uses grid t // block_size); length () int32.
    Returns (attn (H, d) — softmax-normalized over history only,
             denom (H,) — softmax partition over history,
             mx (H,) — max score over history, floored at -1e29).
    Empty history (length==0) yields denom=0 so the merge reduces to the
    current token alone.
    """
    h, s, d = kq.shape
    k = kq.astype(jnp.float32) * ref.expand_block_scales(k_scales, s, block_size)
    v = vq.astype(jnp.float32) * ref.expand_block_scales(v_scales, s, block_size)
    scores = jnp.einsum("hd,htd->ht", q, k) / jnp.sqrt(jnp.float32(d))
    idx = jax.lax.broadcasted_iota(jnp.int32, (h, s), 1)
    scores = jnp.where(idx < length, scores, jnp.float32(-1e30))
    mx = jnp.max(scores, axis=-1)  # (H,)  == -1e30 when empty
    mx_safe = jnp.maximum(mx, -1e29)
    e = jnp.exp(scores - mx_safe[:, None])
    e = jnp.where(idx < length, e, 0.0)
    denom = jnp.sum(e, axis=-1)  # (H,)
    denom_safe = jnp.where(denom > 0, denom, 1.0)
    attn = jnp.einsum("ht,htd->hd", e, v) / denom_safe[:, None]
    return attn, denom, mx_safe


def _decode_core(spec: ModelSpec, flat_params, token, pos,
                 kq, k_scales, vq, v_scales, history_attention):
    """Shared decode-step body; `history_attention` computes the masked
    attention over the INT8 history (plain-XLA or Pallas-fused)."""
    emb, layers, ln_f = spec.unflatten(flat_params)
    h, d = spec.heads, spec.head_dim
    x = emb[token]  # (M,)
    pos1 = pos.reshape(1)

    k_news, v_news = [], []
    for i, lp in enumerate(layers):
        xn = rmsnorm(x, lp["ln1"])
        q = _rope((xn @ lp["wq"]).reshape(1, h, d).transpose(1, 0, 2), pos1)
        k_new = _rope((xn @ lp["wk"]).reshape(1, h, d).transpose(1, 0, 2), pos1)
        v_new = (xn @ lp["wv"]).reshape(1, h, d).transpose(1, 0, 2)
        k_news.append(k_new[:, 0, :])  # (H, d)
        v_news.append(v_new[:, 0, :])

        qh = q[:, 0, :]
        ks_i = None if k_scales is None else k_scales[i]
        vs_i = None if v_scales is None else v_scales[i]
        attn_hist, denom_hist, max_hist = history_attention(
            qh, kq[i], ks_i, vq[i], vs_i, pos)
        # Streaming-softmax merge of the history with the current token
        # (the current token's K/V are still FP32 — they are quantized by
        # the Rust cache manager *after* this step).
        s_cur = jnp.einsum("hd,hd->h", qh, k_new[:, 0, :])
        s_cur = s_cur / jnp.sqrt(jnp.float32(d))  # (H,)
        m = jnp.maximum(max_hist, s_cur)
        w_hist = jnp.exp(max_hist - m)[:, None]
        w_cur = jnp.exp(s_cur - m)[:, None]
        num = attn_hist * denom_hist[:, None] * w_hist + w_cur * v_new[:, 0, :]
        den = denom_hist[:, None] * w_hist + w_cur
        attn = num / den  # (H, d)

        x = x + attn.reshape(-1) @ lp["wo"]
        xn = rmsnorm(x, lp["ln2"])
        x = x + gelu(xn @ lp["w1"]) @ lp["w2"]

    x = rmsnorm(x, ln_f)
    logits = x @ emb.T
    return logits, jnp.stack(k_news), jnp.stack(v_news)


def decode_step(spec: ModelSpec, flat_params, token, pos,
                kq, k_scales, vq, v_scales):
    """Single-token forward over the INT8 cache (plain-XLA history attn).

    token: () int32; pos: () int32 — index this token will occupy (== number
    of valid cache rows). kq/vq: (L, H, S, d) int8; scales: (L, H, B, d)
    f32 per-block grids, B = ceil(S / block_size) — row t dequantizes
    through block t // block_size's grid (the Rust staged decode ABI,
    rust/src/kvcache/policy.rs).
    Returns (logits (V,), k_new (L, H, d) f32, v_new (L, H, d) f32).

    The cache is *not* updated here: quantize-and-append is owned by the
    Rust cache manager (frozen per-block grids, clamped appends into the
    last block's grid), keeping this artifact free of scatter ops and the
    paged layout opaque to XLA.
    """

    def hist(qh, kqi, ksi, vqi, vsi, length):
        return _attended_history(qh, kqi, ksi, vqi, vsi, length,
                                 spec.block_size)

    return _decode_core(spec, flat_params, token, pos,
                        kq, k_scales, vq, v_scales, hist)


def decode_step_pallas(spec: ModelSpec, flat_params, token, pos,
                       kq, k_scales, vq, v_scales):
    """decode_step whose history attention runs through the fused Pallas
    dequant-attention kernel. The kernel returns the normalized history
    attention; denom/max for the streaming merge come from the shared
    score row, which XLA CSEs with the kernel's own computation."""

    def hist(qh, kqi, ksi, vqi, vsi, length):
        attn = kernels.dequant_attention_decode(
            qh, kqi, ksi, vqi, vsi, length, block_size=spec.block_size)
        _, denom, mx = _attended_history(qh, kqi, ksi, vqi, vsi, length,
                                         spec.block_size)
        return attn, denom, mx

    return _decode_core(spec, flat_params, token, pos,
                        kq, k_scales, vq, v_scales, hist)


def decode_step_fp32(spec: ModelSpec, flat_params, token, pos,
                     k_cache, v_cache):
    """FP32-cache decode baseline (no quantization): same signature shape
    as `decode_step` but with f32 (L, H, S, d) caches and no scales. This
    is the serving bench's apples-to-apples comparison point — 4× the
    cache traffic and memory of the INT8 path."""

    def hist(qh, ki, _ks, vi, _vs, length):
        h, s, d = ki.shape
        scores = jnp.einsum("hd,htd->ht", qh, ki) / jnp.sqrt(jnp.float32(d))
        idx = jax.lax.broadcasted_iota(jnp.int32, (h, s), 1)
        scores = jnp.where(idx < length, scores, jnp.float32(-1e30))
        mx = jnp.max(scores, axis=-1)
        mx_safe = jnp.maximum(mx, -1e29)
        e = jnp.exp(scores - mx_safe[:, None])
        e = jnp.where(idx < length, e, 0.0)
        denom = jnp.sum(e, axis=-1)
        denom_safe = jnp.where(denom > 0, denom, 1.0)
        attn = jnp.einsum("ht,htd->hd", e, vi) / denom_safe[:, None]
        return attn, denom, mx_safe

    return _decode_core(spec, flat_params, token, pos,
                        k_cache, None, v_cache, None, hist)


def attention_error_probe(q, k, kq, scales):
    """Fig-4 right panel: mean |qK^T − qK̂^T| over sampled queries.

    q: (Nq, D) f32; k: (T, D) f32 original; kq: (T, D) int8; scales: (D,).
    Lowered per bench shape so the Rust harness can run it via PJRT.
    """
    k_hat = kq.astype(jnp.float32) * scales
    s = q @ k.T
    s_hat = q @ k_hat.T
    return jnp.mean(jnp.abs(s - s_hat))
