"""AOT lowering: jax/Pallas entry points → artifacts/*.hlo.txt + manifest.

The interchange format is **HLO text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
XLA (xla_extension 0.5.1) rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids, so text round-trips cleanly. Lowering goes through
stablehlo → XlaComputation with ``return_tuple=True``; the Rust side
unwraps with ``decompose_tuple``.

Entry points:

* per bench shape (configs/bench_shapes.json, ci + paper):
  ``scales``, ``quantize_{naive,tiled,coarsened,vectorized}``,
  ``dequantize_{...}``, ``quantize_fused`` (single-pass Pallas),
  ``quantize_ref`` (pure-jnp, XLA-codegen ablation baseline),
  ``attnerr`` (Fig-4 attention-score-error probe, token-subsampled).
* per model config: ``prefill``, ``decode`` (plain-XLA history attention)
  and ``decode_pallas`` (fused Pallas dequant-attention history).

The manifest (artifacts/manifest.json) records every entry's input/output
dtypes+shapes plus the model param ABI so the Rust runtime can validate
literals before execution.

Usage: ``python -m compile.aot --out-dir ../artifacts [--shapes ci|paper|all]
        [--models kvq-3m,kvq-25m] [--quick]``
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_mod
from .kernels import quant as kernels
from .kernels import ref

# Number of query rows for the attention-error probe (Fig 4 right panel).
ATTNERR_QUERIES = 64
# Token-row cap for the probe: qK^T at full T=131072, D=8192 is ~68 GFLOP —
# minutes on this 1-core box. The metric is a mean over (query, token)
# pairs, so a uniform row subsample is an unbiased estimator of it.
ATTNERR_MAX_TOKENS = 8192


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(avals):
    return [{"dtype": str(a.dtype), "shape": list(a.shape)} for a in avals]


class Builder:
    def __init__(self, out_dir: str, force: bool = False):
        self.out_dir = out_dir
        self.force = force
        self.entries = []
        os.makedirs(out_dir, exist_ok=True)

    def add(self, name: str, fn, arg_specs, kind: str, meta=None):
        """Lower ``fn`` at ``arg_specs`` (ShapeDtypeStructs) and record it."""
        path = f"{name}.hlo.txt"
        full = os.path.join(self.out_dir, path)
        t0 = time.time()
        lowered = jax.jit(fn).lower(*arg_specs)
        out_avals = jax.eval_shape(fn, *arg_specs)
        if not isinstance(out_avals, (tuple, list)):
            out_avals = (out_avals,)
        text = to_hlo_text(lowered)
        with open(full, "w") as f:
            f.write(text)
        self.entries.append(
            {
                "name": name,
                "path": path,
                "kind": kind,
                "inputs": _sig(arg_specs),
                "outputs": _sig(out_avals),
                "meta": meta or {},
            }
        )
        print(f"  lowered {name:42s} {time.time() - t0:6.2f}s "
              f"({len(text) // 1024} KiB)", flush=True)

    def write_manifest(self, extra):
        man = {"version": 1, "entries": self.entries}
        man.update(extra)
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(man, f, indent=1)
        print(f"manifest: {len(self.entries)} entries")


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i8(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int8)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def build_shape_entries(b: Builder, t: int, d: int, tag: str):
    """All kernel entry points for one (T, D) bench shape."""
    b.add(f"scales_{tag}", kernels.compute_scales, [f32(t, d)],
          "scales", {"tokens": t, "dim": d})
    for variant, (qf, df) in kernels.VARIANTS.items():
        b.add(f"quantize_{variant}_{tag}", qf, [f32(t, d), f32(d)],
              "quantize", {"variant": variant, "tokens": t, "dim": d})
        b.add(f"dequantize_{variant}_{tag}", df, [i8(t, d), f32(d)],
              "dequantize", {"variant": variant, "tokens": t, "dim": d})
    b.add(f"quantize_fused_{tag}", kernels.quantize_fused, [f32(t, d)],
          "quantize_fused", {"tokens": t, "dim": d})
    b.add(f"quantize_ref_{tag}", ref.quantize_fused, [f32(t, d)],
          "quantize_ref", {"tokens": t, "dim": d})
    tsub = min(t, ATTNERR_MAX_TOKENS)
    b.add(
        f"attnerr_{tag}",
        model_mod.attention_error_probe,
        [f32(ATTNERR_QUERIES, d), f32(tsub, d), i8(tsub, d), f32(d)],
        "attnerr",
        {"tokens": t, "dim": d, "probe_tokens": tsub,
         "queries": ATTNERR_QUERIES},
    )


def build_model_entries(b: Builder, spec: model_mod.ModelSpec):
    """prefill / decode / decode_pallas for one model config."""
    pspecs = [f32(*shape) for _, shape in spec.param_specs()]
    s, l_, h, dh = spec.max_seq, spec.layers, spec.heads, spec.head_dim
    v = spec.vocab
    meta = {
        "model": spec.name,
        "vocab": v, "layers": l_, "heads": h, "head_dim": dh,
        "d_ff": spec.d_ff, "max_seq": s, "block_size": spec.block_size,
        "params": [{"name": n, "shape": list(sh)}
                   for n, sh in spec.param_specs()],
    }
    b.add(
        f"prefill_{spec.name}",
        lambda *a: model_mod.prefill(spec, a[:-2], a[-2], a[-1]),
        pspecs + [i32(s), i32()],
        "prefill",
        meta,
    )
    # Bucketed prefill variants: prompts are padded to the smallest bucket
    # >= len instead of max_seq, cutting O(S²) prefill cost for short
    # prompts (the L3 perf pass's TTFT optimization — EXPERIMENTS.md §Perf).
    bucket = 64
    while bucket < s:
        b.add(
            f"prefill_{spec.name}_s{bucket}",
            lambda *a, bk=bucket: model_mod.prefill(spec, a[:-2], a[-2], a[-1]),
            pspecs + [i32(bucket), i32()],
            "prefill_bucket",
            {**meta, "bucket": bucket},
        )
        bucket *= 2
    # Per-block scale grids: B = ceil(S / block_size), matching what the
    # Rust runner stages for decode (rust/src/model/runner.rs).
    bcnt = -(-s // spec.block_size)
    meta = {**meta, "scale_blocks": bcnt}
    cache = [i8(l_, h, s, dh), f32(l_, h, bcnt, dh),
             i8(l_, h, s, dh), f32(l_, h, bcnt, dh)]
    b.add(
        f"decode_{spec.name}",
        lambda *a: model_mod.decode_step(spec, a[:-6], a[-6], a[-5],
                                         a[-4], a[-3], a[-2], a[-1]),
        pspecs + [i32(), i32()] + cache,
        "decode",
        meta,
    )
    b.add(
        f"decode_pallas_{spec.name}",
        lambda *a: model_mod.decode_step_pallas(spec, a[:-6], a[-6], a[-5],
                                                a[-4], a[-3], a[-2], a[-1]),
        pspecs + [i32(), i32()] + cache,
        "decode_pallas",
        meta,
    )
    cache32 = [f32(l_, h, s, dh), f32(l_, h, s, dh)]
    b.add(
        f"decode_fp32_{spec.name}",
        lambda *a: model_mod.decode_step_fp32(spec, a[:-4], a[-4], a[-3],
                                              a[-2], a[-1]),
        pspecs + [i32(), i32()] + cache32,
        "decode_fp32",
        meta,
    )


def load_shapes_config():
    here = os.path.dirname(os.path.abspath(__file__))
    path = os.path.join(here, "..", "..", "configs", "bench_shapes.json")
    with open(path) as f:
        return json.load(f)


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--shapes", default="all", choices=["ci", "paper", "all"])
    p.add_argument("--models", default="kvq-3m,kvq-25m")
    p.add_argument("--quick", action="store_true",
                   help="single small shape + tiny model (test runs)")
    args = p.parse_args(argv)

    cfg = load_shapes_config()
    b = Builder(args.out_dir)

    shape_sets = []
    if args.quick:
        shape_sets = [("ci", [cfg["ci"][0]])]
        model_names = ["kvq-3m"]
    else:
        if args.shapes in ("ci", "all"):
            shape_sets.append(("ci", cfg["ci"]))
        if args.shapes in ("paper", "all"):
            shape_sets.append(("paper", cfg["paper"]))
        model_names = [m for m in args.models.split(",") if m]

    seen = set()
    shape_index = []
    for setname, shapes in shape_sets:
        for sh in shapes:
            t, d = sh["tokens"], sh["dim"]
            tag = f"{t}x{d}"
            shape_index.append(
                {"set": setname, "name": sh["name"], "tokens": t,
                 "dim": d, "tag": tag, "desc": sh.get("desc", "")})
            if tag in seen:
                continue
            seen.add(tag)
            print(f"[shape {tag}]", flush=True)
            build_shape_entries(b, t, d, tag)

    models_meta = []
    for mc in cfg["models"]:
        if mc["name"] not in model_names:
            continue
        spec = model_mod.ModelSpec(
            name=mc["name"], vocab=mc["vocab"], layers=mc["layers"],
            heads=mc["heads"], head_dim=mc["head_dim"], d_ff=mc["d_ff"],
            max_seq=mc["max_seq"], block_size=mc["block_size"])
        print(f"[model {spec.name}]", flush=True)
        build_model_entries(b, spec)
        models_meta.append(mc)

    b.write_manifest({"shapes": shape_index, "models": models_meta})
    return 0


if __name__ == "__main__":
    sys.exit(main())
