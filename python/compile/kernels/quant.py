"""Pallas kernels for per-channel INT8 KV-cache quantization.

The paper implements four CUDA kernel variants — naive, tiled, coarsened,
vectorized — distinguished by how they map work onto the GPU memory
hierarchy. On TPU-shaped hardware the analogous levers are the Pallas grid
and BlockSpecs (the HBM↔VMEM schedule), so each variant here re-expresses
the same insight (DESIGN.md §Hardware-Adaptation):

* ``quantize_naive``      — small (Rt, Dt) blocks on a 2-D grid, and the
  *full* scales row shipped to VMEM on every grid step: the analog of every
  CUDA thread redundantly loading scales from global memory.
* ``quantize_tiled``      — same 2-D grid, but scales get their own (1, Dt)
  BlockSpec whose index map depends only on the column coordinate: the tile
  is staged once per column strip and reused across the row dimension —
  the shared-memory staging analog.
* ``quantize_coarsened``  — 1-D grid over column strips; each step owns the
  whole (T, Dt) strip: one scale fetch amortized over many rows, the
  thread-coarsening analog.
* ``quantize_vectorized`` — 1-D grid over row strips with full-width
  (Rt, D) lane-aligned blocks: the widest legal memory transactions, the
  float4/char4 analog.

All kernels are lowered with ``interpret=True`` so they become plain HLO and
run on any PJRT backend (the CPU plugin cannot execute Mosaic custom-calls);
real-TPU performance is estimated in DESIGN.md §Perf from VMEM footprints.

Rounding is half-away-from-zero (see ref.py) and results are clamped to
[-127, 127]; zero-scale (all-zero) columns quantize to 0.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QMAX = 127.0

# Variant registry: name -> (quantize_fn, dequantize_fn). Populated at the
# bottom of this module; aot.py and the tests iterate over it.
VARIANTS = {}


def _round_half_away(x):
    return jnp.sign(x) * jnp.floor(jnp.abs(x) + 0.5)


def _quant_block(vals, scales):
    """Shared per-block math: divide, round, clamp, zero-scale guard."""
    vals = vals.astype(jnp.float32)
    safe = jnp.where(scales > 0.0, scales, 1.0)
    q = _round_half_away(vals / safe)
    q = jnp.clip(q, -QMAX, QMAX)
    q = jnp.where(scales > 0.0, q, 0.0)
    return q.astype(jnp.int8)


def _pick_tile(n, target):
    """Largest divisor of ``n`` that is <= target (>=1). Keeps blocks legal
    for arbitrary shapes without masking logic in every kernel."""
    t = min(n, target)
    while n % t:
        t -= 1
    return t


def _grid_tile(n, parts, floor):
    """Tile size that splits ``n`` into about ``parts`` grid steps, but
    never below ``floor`` elements per tile.

    Substrate note (DESIGN.md §Hardware-Adaptation): on a real GPU the
    paper's naive kernel launches T·D threads that run *in parallel*; under
    interpret-mode lowering the grid becomes a **sequential** XLA while
    loop whose per-step cost includes a full output-buffer carry. Keeping
    the step count bounded (≈``parts``² for 2-D grids) preserves each
    variant's relative granularity — naive/tiled still take ~16× more grid
    steps and re-fetch scales redundantly compared to vectorized — without
    the O(steps × T × D) blow-up that a thread-per-element grid would cost
    on this substrate.
    """
    return _pick_tile(n, max(floor, -(-n // parts)))


# ---------------------------------------------------------------------------
# Scale computation — one pass of column-wise abs-max (Algorithm 1).
# ---------------------------------------------------------------------------


def compute_scales(k, *, row_parts=16, col_parts=4):
    """Per-channel scales via a tiled abs-max reduction.

    Grid is (column strips, row strips) with rows innermost so each column
    strip's running max accumulates in its VMEM-resident output block — the
    Pallas analog of the paper's suggested ``__shfl_down_sync`` reduction
    tree (future work §8.2), expressed as a block-level reduction instead.
    """
    t, d = k.shape
    rt = _grid_tile(t, row_parts, 256)
    dt = _grid_tile(d, col_parts, 128)

    def kernel(k_ref, out_ref):
        r = pl.program_id(1)
        block_max = jnp.max(jnp.abs(k_ref[...].astype(jnp.float32)), axis=0)

        @pl.when(r == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        out_ref[...] = jnp.maximum(out_ref[...], block_max[None, :])

    out = pl.pallas_call(
        kernel,
        grid=(d // dt, t // rt),
        in_specs=[pl.BlockSpec((rt, dt), lambda c, r: (r, c))],
        out_specs=pl.BlockSpec((1, dt), lambda c, r: (0, c)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=True,
    )(k)
    return out[0] / QMAX


# ---------------------------------------------------------------------------
# Quantize variants.
# ---------------------------------------------------------------------------


def quantize_naive(k, scales, *, row_parts=16, col_parts=16):
    """2-D grid of small blocks; full scales row refetched every step."""
    t, d = k.shape
    rt = _grid_tile(t, row_parts, 8)
    dt = _grid_tile(d, col_parts, 128)

    def kernel(k_ref, s_ref, o_ref):
        c = pl.program_id(1)
        # The whole (1, D) scales row is resident; slice out our strip —
        # the redundant-load pattern of the paper's naive kernel.
        s = jax.lax.dynamic_slice(s_ref[...], (0, c * dt), (1, dt))
        o_ref[...] = _quant_block(k_ref[...], s)

    return pl.pallas_call(
        kernel,
        grid=(t // rt, d // dt),
        in_specs=[
            pl.BlockSpec((rt, dt), lambda r, c: (r, c)),
            pl.BlockSpec((1, d), lambda r, c: (0, 0)),  # full row, every step
        ],
        out_specs=pl.BlockSpec((rt, dt), lambda r, c: (r, c)),
        out_shape=jax.ShapeDtypeStruct((t, d), jnp.int8),
        interpret=True,
    )(k, scales.reshape(1, d))


def quantize_tiled(k, scales, *, row_parts=16, col_parts=16):
    """2-D grid; scales tile staged per column strip and reused across rows."""
    t, d = k.shape
    rt = _grid_tile(t, row_parts, 8)
    dt = _grid_tile(d, col_parts, 128)

    def kernel(k_ref, s_ref, o_ref):
        o_ref[...] = _quant_block(k_ref[...], s_ref[...])

    return pl.pallas_call(
        kernel,
        grid=(d // dt, t // rt),  # rows innermost: scale tile reused in VMEM
        in_specs=[
            pl.BlockSpec((rt, dt), lambda c, r: (r, c)),
            pl.BlockSpec((1, dt), lambda c, r: (0, c)),  # staged per strip
        ],
        out_specs=pl.BlockSpec((rt, dt), lambda c, r: (r, c)),
        out_shape=jax.ShapeDtypeStruct((t, d), jnp.int8),
        interpret=True,
    )(k, scales.reshape(1, d))


def quantize_coarsened(k, scales, *, col_parts=8):
    """1-D grid over column strips; each step owns the whole strip."""
    t, d = k.shape
    dt = _grid_tile(d, col_parts, 128)

    def kernel(k_ref, s_ref, o_ref):
        o_ref[...] = _quant_block(k_ref[...], s_ref[...])

    return pl.pallas_call(
        kernel,
        grid=(d // dt,),
        in_specs=[
            pl.BlockSpec((t, dt), lambda c: (0, c)),
            pl.BlockSpec((1, dt), lambda c: (0, c)),
        ],
        out_specs=pl.BlockSpec((t, dt), lambda c: (0, c)),
        out_shape=jax.ShapeDtypeStruct((t, d), jnp.int8),
        interpret=True,
    )(k, scales.reshape(1, d))


def quantize_vectorized(k, scales, *, row_parts=1):
    """1-D grid over row strips with full-width lane-aligned blocks."""
    t, d = k.shape
    rt = _grid_tile(t, row_parts, 8)

    def kernel(k_ref, s_ref, o_ref):
        o_ref[...] = _quant_block(k_ref[...], s_ref[...])

    return pl.pallas_call(
        kernel,
        grid=(t // rt,),
        in_specs=[
            pl.BlockSpec((rt, d), lambda r: (r, 0)),
            pl.BlockSpec((1, d), lambda r: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rt, d), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), jnp.int8),
        interpret=True,
    )(k, scales.reshape(1, d))


# ---------------------------------------------------------------------------
# Dequantize variants (mirrors of the above; naive + vectorized cover the
# paper's measured dequant path, coarsened/tiled included for symmetry).
# ---------------------------------------------------------------------------


def _dequant_call(k8, scales, grid, in_specs, out_specs):
    t, d = k8.shape

    def kernel(q_ref, s_ref, o_ref):
        o_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...]

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=jax.ShapeDtypeStruct((t, d), jnp.float32),
        interpret=True,
    )(k8, scales.reshape(1, d))


def dequantize_naive(k8, scales, *, row_parts=16, col_parts=16):
    t, d = k8.shape
    rt, dt = _grid_tile(t, row_parts, 8), _grid_tile(d, col_parts, 128)
    return _dequant_call(
        k8,
        scales,
        (t // rt, d // dt),
        [
            pl.BlockSpec((rt, dt), lambda r, c: (r, c)),
            pl.BlockSpec((1, dt), lambda r, c: (0, c)),
        ],
        pl.BlockSpec((rt, dt), lambda r, c: (r, c)),
    )


def dequantize_tiled(k8, scales, *, row_parts=16, col_parts=16):
    t, d = k8.shape
    rt, dt = _grid_tile(t, row_parts, 8), _grid_tile(d, col_parts, 128)
    return _dequant_call(
        k8,
        scales,
        (d // dt, t // rt),
        [
            pl.BlockSpec((rt, dt), lambda c, r: (r, c)),
            pl.BlockSpec((1, dt), lambda c, r: (0, c)),
        ],
        pl.BlockSpec((rt, dt), lambda c, r: (r, c)),
    )


def dequantize_coarsened(k8, scales, *, col_parts=8):
    t, d = k8.shape
    dt = _grid_tile(d, col_parts, 128)
    return _dequant_call(
        k8,
        scales,
        (d // dt,),
        [
            pl.BlockSpec((t, dt), lambda c: (0, c)),
            pl.BlockSpec((1, dt), lambda c: (0, c)),
        ],
        pl.BlockSpec((t, dt), lambda c: (0, c)),
    )


def dequantize_vectorized(k8, scales, *, row_parts=1):
    t, d = k8.shape
    rt = _grid_tile(t, row_parts, 8)
    return _dequant_call(
        k8,
        scales,
        (t // rt,),
        [
            pl.BlockSpec((rt, d), lambda r: (r, 0)),
            pl.BlockSpec((1, d), lambda r: (0, 0)),
        ],
        pl.BlockSpec((rt, d), lambda r: (r, 0)),
    )


# ---------------------------------------------------------------------------
# Fused scales + quantize — the production cache-writer path: one HBM read
# of K produces both the scales and the INT8 matrix.
# ---------------------------------------------------------------------------


def quantize_fused(k, *, col_parts=8):
    """Single pallas_call emitting (K_int8, scales).

    Grid over column strips; each step reduces its (T, Dt) strip to scales
    then quantizes it while the strip is still VMEM-resident — the paper's
    two passes (Algorithm 1 + eq. 7) collapsed into one HBM traversal.
    """
    t, d = k.shape
    dt = _grid_tile(d, col_parts, 128)

    def kernel(k_ref, q_ref, s_ref):
        vals = k_ref[...].astype(jnp.float32)
        s = jnp.max(jnp.abs(vals), axis=0, keepdims=True) / QMAX
        s_ref[...] = s
        q_ref[...] = _quant_block(vals, s)

    kq, s = pl.pallas_call(
        kernel,
        grid=(d // dt,),
        in_specs=[pl.BlockSpec((t, dt), lambda c: (0, c))],
        out_specs=[
            pl.BlockSpec((t, dt), lambda c: (0, c)),
            pl.BlockSpec((1, dt), lambda c: (0, c)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, d), jnp.int8),
            jax.ShapeDtypeStruct((1, d), jnp.float32),
        ],
        interpret=True,
    )(k)
    return kq, s[0]


# ---------------------------------------------------------------------------
# Fused dequant + attention — the decode hot path: read the INT8 cache,
# dequantize in VMEM, and run single-query attention without ever
# materializing the FP32 cache in HBM. This is the kernel the paper's
# future-work section says a serving integration needs.
# ---------------------------------------------------------------------------


def dequant_attention_decode(q, kq, k_scales, vq, v_scales, length, *,
                             block_size=None):
    """Single-token attention over a quantized (H, T, d) cache.

    q: (H, d) f32; kq/vq: (H, T, d) int8; *_scales: (H, B, d) f32 frozen
    per-block grids, B = ceil(T / block_size) — cache row t dequantizes
    through block ``t // block_size``'s grid, the same block-granular
    freeze the Rust cache manager stages for decode
    (rust/src/kvcache/policy.rs). ``block_size`` defaults to ceil(T / B);
    a legacy (H, d) single grid per head is accepted as B = 1.
    length: int32 scalar — number of valid cache rows. Returns (H, d).

    Grid over heads; each step stages one head's INT8 K and V strips plus
    its B scale grids, expands them to per-row factors and dequantizes in
    VMEM, then computes masked softmax(qKᵀ/√d)·V. INT8 staging means the
    HBM traffic is 4× smaller than an FP32 cache — the end-to-end benefit
    the paper's §8.2 integration asks for.
    """
    h, t, d = kq.shape
    if k_scales.ndim == 2:
        k_scales = k_scales[:, None, :]
        v_scales = v_scales[:, None, :]
    b = k_scales.shape[1]
    bs = block_size if block_size is not None else -(-t // b)
    assert b * bs >= t, "per-block grids must cover every cache row"

    def kernel(len_ref, q_ref, kq_ref, ks_ref, vq_ref, vs_ref, o_ref):
        n = len_ref[0]
        ks = jnp.repeat(ks_ref[0], bs, axis=0)[:t]  # (T, d) row factors
        vs = jnp.repeat(vs_ref[0], bs, axis=0)[:t]
        k = kq_ref[0].astype(jnp.float32) * ks  # (T, d)
        v = vq_ref[0].astype(jnp.float32) * vs
        qv = q_ref[...]  # (1, d)
        scores = (qv @ k.T) / jnp.sqrt(jnp.float32(d))  # (1, T)
        idx = jax.lax.broadcasted_iota(jnp.int32, (1, t), 1)
        scores = jnp.where(idx < n, scores, -1e30)
        m = jnp.max(scores, axis=-1, keepdims=True)
        e = jnp.exp(scores - m)
        w = e / jnp.sum(e, axis=-1, keepdims=True)
        o_ref[...] = w @ v  # (1, d)

    return pl.pallas_call(
        kernel,
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, t, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, b, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, t, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, b, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, d), jnp.float32),
        interpret=True,
    )(length.reshape(1), q, kq, k_scales, vq, v_scales)


# ---------------------------------------------------------------------------
# Registry.
# ---------------------------------------------------------------------------

VARIANTS.update(
    {
        "naive": (quantize_naive, dequantize_naive),
        "tiled": (quantize_tiled, dequantize_tiled),
        "coarsened": (quantize_coarsened, dequantize_coarsened),
        "vectorized": (quantize_vectorized, dequantize_vectorized),
    }
)


def quantize(k, scales, variant="vectorized", **kw):
    """Dispatch helper used by model.py and aot.py."""
    return VARIANTS[variant][0](k, scales, **kw)


def dequantize(k8, scales, variant="vectorized", **kw):
    return VARIANTS[variant][1](k8, scales, **kw)
