"""Pure-jnp reference oracle for the INT8 KV-cache quantization kernels.

This module is the correctness ground truth for every Pallas kernel in
`quant.py` and for the Rust CPU implementation (which mirrors the paper's C
listings). All functions operate on a key/value matrix ``K`` of shape
``(T, D)`` — ``T`` cached tokens by ``D`` head-dimension channels — and use
**per-channel** scales: one scale per column ``d``, eq. (5)/(6) of the paper:

    s_d = max_t |K[t, d]| / 127

Quantization (eq. 7) uses round-half-away-from-zero: the paper's CPU
baseline uses C ``roundf`` (half away from zero) while its GPU kernels use
``__float2int_rn`` (half to even), reconciled there with a ±1 tolerance.
We standardize every implementation in this repo (Pallas + Rust) on
half-away-from-zero and hold them to exact agreement instead.
Dequantization (eq. 8) is ``x_q * s_d``.
"""

from __future__ import annotations

import jax.numpy as jnp

# INT8 symmetric range used throughout the paper: [-127, 127] (not -128,
# keeping the grid symmetric so dequantization has zero bias at 0).
QMAX = 127.0


def round_half_away(x):
    """Round half away from zero, matching C's roundf / Rust's f32::round."""
    return jnp.sign(x) * jnp.floor(jnp.abs(x) + 0.5)


def compute_scales(k):
    """Per-channel scales, eq. (6): s_d = max_t |K[t,d]| / 127.

    Zero columns get scale 0; `quantize` special-cases them (the paper's C
    divides by the scale unguarded — we define 0/0 → 0 instead of NaN).
    """
    return jnp.max(jnp.abs(k), axis=0) / QMAX


def quantize(k, scales):
    """Quantize eq. (7): round(K[t,d] / s_d) clamped to [-127, 127].

    Columns whose scale is 0 (all-zero columns) quantize to 0.
    """
    safe = jnp.where(scales > 0.0, scales, 1.0)
    q = round_half_away(k / safe)
    q = jnp.clip(q, -QMAX, QMAX)
    q = jnp.where(scales > 0.0, q, 0.0)
    return q.astype(jnp.int8)


def dequantize(kq, scales):
    """Dequantize eq. (8): x̂ = x_q * s_d."""
    return kq.astype(jnp.float32) * scales


def quantize_fused(k):
    """Single-pass scales + quantize (what a production cache writer runs)."""
    scales = compute_scales(k)
    return quantize(k, scales), scales


def roundtrip(k):
    """quantize → dequantize; the reconstruction K̂ the error metrics use."""
    kq, scales = quantize_fused(k)
    return dequantize(kq, scales)


# ---------------------------------------------------------------------------
# Error metrics — §7.2/7.3 of the paper.
# ---------------------------------------------------------------------------


def l2_error(a, b):
    """Frobenius/L2 error: sqrt(sum((a-b)^2)). Grows with matrix size."""
    d = (a - b).astype(jnp.float32)
    return jnp.sqrt(jnp.sum(d * d))


def max_abs_error(a, b):
    """Max per-element error; bounded by s_d/2 ≈ 1/(2·127) for U(-1,1)."""
    return jnp.max(jnp.abs(a - b))


def attention_score_error(q, k, k_hat):
    """Mean |q·k - q·k̂| over all (query, token) attention dot products.

    q: (Nq, D) query rows; k, k_hat: (T, D). The paper reports the mean
    absolute difference of the pre-softmax scores (no 1/sqrt(d) factor —
    matching the paper's 'attention dot products').
    """
    s = q @ k.T
    s_hat = q @ k_hat.T
    return jnp.mean(jnp.abs(s - s_hat))


# ---------------------------------------------------------------------------
# Attention reference — used by the fused dequant-attention kernel and the
# L2 model decode step.
# ---------------------------------------------------------------------------


def softmax(x, axis=-1):
    m = jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def expand_block_scales(scales, t, block_size):
    """Per-block frozen grids -> per-row dequant factors.

    scales: (H, B, d) with B = ceil(t / block_size); cache row r
    dequantizes through block ``r // block_size``'s grid — the same
    block-granular freeze the Rust cache manager stages for decode
    (rust/src/kvcache/policy.rs). Returns (H, t, d).
    """
    return jnp.repeat(scales, block_size, axis=1)[:, :t, :]


def attention_decode(q, kq, k_scales, vq, v_scales, length=None,
                     block_size=None):
    """Single-token decode attention over a quantized cache.

    q: (H, d) one query per head; kq/vq: (H, T, d) int8; scales: (H, d)
    for a single frozen grid per head, or (H, B, d) per-block grids
    (``block_size`` rows each, defaulting to ceil(T / B)).
    ``length``: optional valid-prefix length (int scalar); positions >= length
    are masked out (the cache is allocated to capacity T but only partially
    filled during generation). Returns (H, d) attention output.
    """
    if k_scales.ndim == 3:
        t = kq.shape[1]
        bs = block_size if block_size is not None else -(-t // k_scales.shape[1])
        k = kq.astype(jnp.float32) * expand_block_scales(k_scales, t, bs)
        v = vq.astype(jnp.float32) * expand_block_scales(v_scales, t, bs)
    else:
        k = kq.astype(jnp.float32) * k_scales[:, None, :]
        v = vq.astype(jnp.float32) * v_scales[:, None, :]
    d = q.shape[-1]
    scores = jnp.einsum("hd,htd->ht", q, k) / jnp.sqrt(jnp.float32(d))
    if length is not None:
        t = kq.shape[1]
        mask = jnp.arange(t)[None, :] < length
        scores = jnp.where(mask, scores, -1e30)
    w = softmax(scores)
    return jnp.einsum("ht,htd->hd", w, v)
