"""AOT pipeline: lowering produces parseable HLO text and a manifest whose
signatures match what the Rust runtime will feed each executable."""

import json
import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def quick_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    aot.main(["--out-dir", str(out), "--quick"])
    return out


class TestManifest:
    def test_manifest_exists_and_parses(self, quick_artifacts):
        with open(quick_artifacts / "manifest.json") as f:
            man = json.load(f)
        assert man["version"] == 1
        assert len(man["entries"]) >= 14
        names = {e["name"] for e in man["entries"]}
        assert "quantize_vectorized_2048x128" in names
        assert "prefill_kvq-3m" in names
        assert "decode_kvq-3m" in names

    def test_every_entry_file_exists_nonempty(self, quick_artifacts):
        with open(quick_artifacts / "manifest.json") as f:
            man = json.load(f)
        for e in man["entries"]:
            p = quick_artifacts / e["path"]
            assert p.exists(), e["name"]
            text = p.read_text()
            assert text.startswith("HloModule"), e["name"]
            assert "ENTRY" in text

    def test_quantize_signature(self, quick_artifacts):
        with open(quick_artifacts / "manifest.json") as f:
            man = json.load(f)
        e = {x["name"]: x for x in man["entries"]}["quantize_vectorized_2048x128"]
        assert e["inputs"] == [
            {"dtype": "float32", "shape": [2048, 128]},
            {"dtype": "float32", "shape": [128]},
        ]
        assert e["outputs"] == [{"dtype": "int8", "shape": [2048, 128]}]

    def test_decode_signature_shapes(self, quick_artifacts):
        with open(quick_artifacts / "manifest.json") as f:
            man = json.load(f)
        e = {x["name"]: x for x in man["entries"]}["decode_kvq-3m"]
        meta = e["meta"]
        l_, h, s, d = meta["layers"], meta["heads"], meta["max_seq"], meta["head_dim"]
        n_params = len(meta["params"])
        assert len(e["inputs"]) == n_params + 2 + 4
        # Cache tensors come last: kq, ks, vq, vs. Scales are per-block
        # grids (B = ceil(S / block_size), the staged decode ABI).
        b = -(-s // meta["block_size"])
        assert meta["scale_blocks"] == b
        assert e["inputs"][-4] == {"dtype": "int8", "shape": [l_, h, s, d]}
        assert e["inputs"][-3] == {"dtype": "float32", "shape": [l_, h, b, d]}
        assert e["outputs"][0] == {"dtype": "float32", "shape": [meta["vocab"]]}
        assert e["outputs"][1] == {"dtype": "float32", "shape": [l_, h, d]}

    def test_param_abi_recorded(self, quick_artifacts):
        with open(quick_artifacts / "manifest.json") as f:
            man = json.load(f)
        e = {x["name"]: x for x in man["entries"]}["prefill_kvq-3m"]
        params = e["meta"]["params"]
        assert params[0]["name"] == "embedding"
        assert params[-1]["name"] == "ln_f"
        # Input list begins with exactly these params, in order.
        for i, p in enumerate(params):
            assert e["inputs"][i]["shape"] == p["shape"]

    def test_shape_index_covers_sets(self, quick_artifacts):
        with open(quick_artifacts / "manifest.json") as f:
            man = json.load(f)
        assert man["shapes"][0]["tag"] == "2048x128"
        assert man["models"][0]["name"] == "kvq-3m"


class TestShapesConfig:
    def test_paper_table3_is_faithful(self):
        """The 'paper' set must be exactly Table 3 of the paper."""
        cfg = aot.load_shapes_config()
        rows = [(s["tokens"], s["dim"]) for s in cfg["paper"]]
        assert rows == [
            (2048, 128), (16384, 256), (65536, 256), (131072, 256),
            (131072, 1024), (131072, 2048), (131072, 4096), (131072, 8192),
        ]

    def test_ci_set_preserves_d_sweep(self):
        cfg = aot.load_shapes_config()
        dims = [s["dim"] for s in cfg["ci"]]
        assert dims == [d for d in (128, 256, 256, 256, 1024, 2048, 4096, 8192)]

    def test_models_present(self):
        cfg = aot.load_shapes_config()
        names = {m["name"] for m in cfg["models"]}
        assert {"kvq-3m", "kvq-25m"} <= names
