"""L2 model semantics: prefill/decode consistency through the INT8 cache.

The strongest test here is incremental-vs-full: prefilling n+1 tokens must
produce (approximately — the cache is quantized) the same logits as
prefilling n tokens and decoding the (n+1)-th over the quantized cache.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model as model_mod
from compile.kernels import ref

SPEC = model_mod.ModelSpec(
    name="test-tiny", vocab=64, layers=2, heads=2, head_dim=16,
    d_ff=64, max_seq=32, block_size=8)


def _params(spec, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for name, shape in spec.param_specs():
        if name.endswith(("ln1", "ln2")) or name == "ln_f":
            out.append(np.ones(shape, dtype=np.float32))
        else:
            fan_in = shape[0] if len(shape) > 1 else 1
            out.append((rng.normal(size=shape) / np.sqrt(fan_in)).astype(np.float32))
    return [jnp.asarray(p) for p in out]


def _quantize_cache(k_cache, v_cache, n, block_size=SPEC.block_size):
    """Per-(layer, head) per-channel quantization of the first n rows with
    block-granular frozen scales, mirroring what the Rust cache manager
    does after prefill: each block's eq.-6 grid is computed over that
    block's own rows only. Scales come back as (L, H, B, d) with
    B = ceil(S / block_size) — the staged decode ABI."""
    l, h, s, d = k_cache.shape
    b = -(-s // block_size)
    kq = np.zeros((l, h, s, d), dtype=np.int8)
    vq = np.zeros((l, h, s, d), dtype=np.int8)
    ks = np.zeros((l, h, b, d), dtype=np.float32)
    vs = np.zeros((l, h, b, d), dtype=np.float32)
    for li in range(l):
        for hd in range(h):
            for bi in range(b):
                lo, hi = bi * block_size, min((bi + 1) * block_size, n)
                if lo >= hi:
                    break  # blocks past the valid prefix stay zeroed
                ks[li, hd, bi] = np.asarray(ref.compute_scales(k_cache[li, hd, lo:hi]))
                vs[li, hd, bi] = np.asarray(ref.compute_scales(v_cache[li, hd, lo:hi]))
                kq[li, hd, lo:hi] = np.asarray(
                    ref.quantize(k_cache[li, hd, lo:hi], ks[li, hd, bi]))
                vq[li, hd, lo:hi] = np.asarray(
                    ref.quantize(v_cache[li, hd, lo:hi], vs[li, hd, bi]))
    return kq, ks, vq, vs


class TestParamSpecs:
    def test_count_and_shapes(self):
        specs = SPEC.param_specs()
        assert len(specs) == 1 + SPEC.layers * 8 + 1
        m = SPEC.d_model
        assert dict(specs)["embedding"] == (SPEC.vocab, m)
        assert dict(specs)["l0.w1"] == (m, SPEC.d_ff)

    def test_unflatten_roundtrip(self):
        flat = _params(SPEC)
        emb, layers, ln_f = SPEC.unflatten(flat)
        assert emb.shape == (SPEC.vocab, SPEC.d_model)
        assert len(layers) == SPEC.layers
        assert ln_f.shape == (SPEC.d_model,)


class TestPrefill:
    def test_shapes(self):
        flat = _params(SPEC)
        tokens = jnp.zeros(SPEC.max_seq, dtype=jnp.int32)
        logits, kc, vc = model_mod.prefill(SPEC, flat, tokens, jnp.int32(5))
        assert logits.shape == (SPEC.vocab,)
        assert kc.shape == (SPEC.layers, SPEC.heads, SPEC.max_seq, SPEC.head_dim)
        assert vc.shape == kc.shape

    def test_padding_invariance(self):
        """Logits and the valid cache prefix must not depend on pad tokens."""
        flat = _params(SPEC)
        n = 6
        rng = np.random.default_rng(0)
        prompt = rng.integers(0, SPEC.vocab, size=n)
        t1 = np.zeros(SPEC.max_seq, dtype=np.int32)
        t2 = np.full(SPEC.max_seq, SPEC.vocab - 1, dtype=np.int32)
        t1[:n] = prompt
        t2[:n] = prompt
        l1, k1, v1 = model_mod.prefill(SPEC, flat, jnp.asarray(t1), jnp.int32(n))
        l2, k2, v2 = model_mod.prefill(SPEC, flat, jnp.asarray(t2), jnp.int32(n))
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)
        np.testing.assert_allclose(np.asarray(k1)[:, :, :n], np.asarray(k2)[:, :, :n],
                                   atol=1e-5)

    def test_deterministic(self):
        flat = _params(SPEC)
        tokens = jnp.asarray(np.arange(SPEC.max_seq, dtype=np.int32) % SPEC.vocab)
        a = model_mod.prefill(SPEC, flat, tokens, jnp.int32(8))
        b = model_mod.prefill(SPEC, flat, tokens, jnp.int32(8))
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))


class TestDecodeConsistency:
    @pytest.mark.parametrize("n", [1, 4, 9])
    def test_incremental_matches_full(self, n):
        """decode(token n over quantized cache of 0..n-1) ≈ prefill(0..n)."""
        flat = _params(SPEC)
        rng = np.random.default_rng(n)
        tokens = rng.integers(0, SPEC.vocab, size=SPEC.max_seq).astype(np.int32)
        tok = jnp.asarray(tokens)

        # Full prefill over n+1 tokens -> reference logits.
        ref_logits, _, _ = model_mod.prefill(SPEC, flat, tok, jnp.int32(n + 1))

        # Prefill n, quantize cache, decode token n.
        _, kc, vc = model_mod.prefill(SPEC, flat, tok, jnp.int32(n))
        kq, ks, vq, vs = _quantize_cache(np.asarray(kc), np.asarray(vc), n)
        dec_logits, k_new, v_new = model_mod.decode_step(
            SPEC, flat, jnp.int32(tokens[n]), jnp.int32(n),
            jnp.asarray(kq), jnp.asarray(ks), jnp.asarray(vq), jnp.asarray(vs))

        # Quantization perturbs the cache; allow a small tolerance but
        # require the argmax (greedy token) to survive.
        np.testing.assert_allclose(np.asarray(dec_logits), np.asarray(ref_logits),
                                   atol=0.15, rtol=0.1)
        assert int(np.argmax(dec_logits)) == int(np.argmax(ref_logits))

    def test_scales_are_per_block(self):
        """n=9 rows span two blocks; each freezes its own eq.-6 grid."""
        flat = _params(SPEC)
        rng = np.random.default_rng(12)
        tokens = rng.integers(0, SPEC.vocab, size=SPEC.max_seq).astype(np.int32)
        _, kc, vc = model_mod.prefill(SPEC, flat, jnp.asarray(tokens), jnp.int32(9))
        _, ks, _, _ = _quantize_cache(np.asarray(kc), np.asarray(vc), 9)
        b = SPEC.max_seq // SPEC.block_size
        assert ks.shape == (SPEC.layers, SPEC.heads, b, SPEC.head_dim)
        # Block 1 covers a single row, so its grid differs from block 0's.
        assert not np.array_equal(ks[:, :, 0, :], ks[:, :, 1, :])
        # Blocks beyond the valid prefix carry no grid.
        assert (ks[:, :, 2:, :] == 0).all()

    def test_new_kv_matches_prefill_row(self):
        """The decode step's emitted K/V row == prefill's row at that pos."""
        flat = _params(SPEC)
        rng = np.random.default_rng(42)
        tokens = rng.integers(0, SPEC.vocab, size=SPEC.max_seq).astype(np.int32)
        tok = jnp.asarray(tokens)
        n = 5
        _, kc_full, vc_full = model_mod.prefill(SPEC, flat, tok, jnp.int32(n + 1))
        _, kc, vc = model_mod.prefill(SPEC, flat, tok, jnp.int32(n))
        kq, ks, vq, vs = _quantize_cache(np.asarray(kc), np.asarray(vc), n)
        _, k_new, v_new = model_mod.decode_step(
            SPEC, flat, jnp.int32(tokens[n]), jnp.int32(n),
            jnp.asarray(kq), jnp.asarray(ks), jnp.asarray(vq), jnp.asarray(vs))
        # K/V projections at position n depend only on x_n (not the cache),
        # modulo the residual stream, which *does* see quantization error in
        # deeper layers — layer 0 must match tightly.
        np.testing.assert_allclose(np.asarray(k_new)[0], np.asarray(kc_full)[0, :, n],
                                   atol=5e-3)

    def test_pallas_decode_matches_plain(self):
        flat = _params(SPEC)
        rng = np.random.default_rng(7)
        tokens = rng.integers(0, SPEC.vocab, size=SPEC.max_seq).astype(np.int32)
        tok = jnp.asarray(tokens)
        n = 6
        _, kc, vc = model_mod.prefill(SPEC, flat, tok, jnp.int32(n))
        kq, ks, vq, vs = _quantize_cache(np.asarray(kc), np.asarray(vc), n)
        args = (SPEC, flat, jnp.int32(tokens[n]), jnp.int32(n),
                jnp.asarray(kq), jnp.asarray(ks), jnp.asarray(vq), jnp.asarray(vs))
        a = model_mod.decode_step(*args)
        b = model_mod.decode_step_pallas(*args)
        np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[0]), atol=1e-4)
        np.testing.assert_allclose(np.asarray(a[1]), np.asarray(b[1]), atol=1e-5)


class TestGreedyGeneration:
    def test_multi_step_generation_stays_consistent(self):
        """Run 5 greedy steps; at each step the quantized-cache decode must
        pick the same greedy token as a full fp32 prefill of the prefix."""
        flat = _params(SPEC)
        rng = np.random.default_rng(3)
        tokens = np.zeros(SPEC.max_seq, dtype=np.int32)
        tokens[:4] = rng.integers(0, SPEC.vocab, size=4)
        agree = 0
        for step in range(5):
            p = 4 + step  # known-prefix length
            # Reference: fp32 prefill over the full prefix.
            ref_logits, _, _ = model_mod.prefill(
                SPEC, flat, jnp.asarray(tokens), jnp.int32(p))
            # Decode path: quantized cache of rows 0..p-2, feed token p-1.
            _, kc, vc = model_mod.prefill(
                SPEC, flat, jnp.asarray(tokens), jnp.int32(p - 1))
            kq, ks, vq, vs = _quantize_cache(np.asarray(kc), np.asarray(vc), p - 1)
            dec_logits, _, _ = model_mod.decode_step(
                SPEC, flat, jnp.int32(tokens[p - 1]), jnp.int32(p - 1),
                jnp.asarray(kq), jnp.asarray(ks), jnp.asarray(vq), jnp.asarray(vs))
            if int(np.argmax(dec_logits)) == int(np.argmax(ref_logits)):
                agree += 1
            tokens[p] = int(np.argmax(ref_logits))
        assert agree >= 4  # greedy choice survives quantization nearly always
