"""Fused dequant-attention kernel vs the pure-jnp reference, plus the
paper's error-law claims (§7.2/§7.3, Fig 4)."""

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: property sweeps skip where absent
    given = settings = st = None

from compile.kernels import quant, ref


def _cache(h, t, d, seed=0, block_size=None):
    """Quantized (H, T, d) cache with per-block frozen grids.

    ``block_size=None`` uses one grid per head (B = 1 — the legacy
    whole-prompt freeze); otherwise each block's grid is computed over its
    own rows, mirroring the Rust cache manager's block-granular freeze.
    Scales come back as (H, B, d)."""
    bs = block_size or t
    b = -(-t // bs)
    rng = np.random.default_rng(seed)
    k = rng.normal(size=(h, t, d)).astype(np.float32)
    v = rng.normal(size=(h, t, d)).astype(np.float32)
    ks = np.zeros((h, b, d), dtype=np.float32)
    vs = np.zeros((h, b, d), dtype=np.float32)
    k8 = np.zeros((h, t, d), dtype=np.int8)
    v8 = np.zeros((h, t, d), dtype=np.int8)
    for i in range(h):
        for bi in range(b):
            lo, hi = bi * bs, min((bi + 1) * bs, t)
            ks[i, bi] = np.asarray(ref.compute_scales(k[i, lo:hi]))
            vs[i, bi] = np.asarray(ref.compute_scales(v[i, lo:hi]))
            k8[i, lo:hi] = np.asarray(ref.quantize(k[i, lo:hi], ks[i, bi]))
            v8[i, lo:hi] = np.asarray(ref.quantize(v[i, lo:hi], vs[i, bi]))
    q = rng.normal(size=(h, d)).astype(np.float32)
    return q, k, v, k8, ks, v8, vs


class TestDequantAttention:
    @pytest.mark.parametrize("length", [1, 7, 16, 32])
    def test_matches_ref(self, length):
        q, _, _, k8, ks, v8, vs = _cache(4, 32, 64, seed=length)
        got = np.asarray(quant.dequant_attention_decode(
            jnp.asarray(q), jnp.asarray(k8), jnp.asarray(ks),
            jnp.asarray(v8), jnp.asarray(vs), jnp.asarray(np.int32(length))))
        want = np.asarray(ref.attention_decode(
            jnp.asarray(q), jnp.asarray(k8), jnp.asarray(ks),
            jnp.asarray(v8), jnp.asarray(vs), length=length))
        np.testing.assert_allclose(got, want, atol=1e-5)

    def test_full_length(self):
        q, _, _, k8, ks, v8, vs = _cache(2, 24, 32, seed=99)
        got = np.asarray(quant.dequant_attention_decode(
            jnp.asarray(q), jnp.asarray(k8), jnp.asarray(ks),
            jnp.asarray(v8), jnp.asarray(vs), jnp.asarray(np.int32(24))))
        want = np.asarray(ref.attention_decode(
            jnp.asarray(q), jnp.asarray(k8), jnp.asarray(ks),
            jnp.asarray(v8), jnp.asarray(vs), length=24))
        np.testing.assert_allclose(got, want, atol=1e-5)

    def test_masked_rows_do_not_leak(self):
        """Garbage beyond `length` must not change the output."""
        q, _, _, k8, ks, v8, vs = _cache(2, 16, 32, seed=1)
        out1 = np.asarray(quant.dequant_attention_decode(
            jnp.asarray(q), jnp.asarray(k8), jnp.asarray(ks),
            jnp.asarray(v8), jnp.asarray(vs), jnp.asarray(np.int32(8))))
        k8b, v8b = k8.copy(), v8.copy()
        k8b[:, 8:, :] = 127
        v8b[:, 8:, :] = -127
        out2 = np.asarray(quant.dequant_attention_decode(
            jnp.asarray(q), jnp.asarray(k8b), jnp.asarray(ks),
            jnp.asarray(v8b), jnp.asarray(vs), jnp.asarray(np.int32(8))))
        np.testing.assert_allclose(out1, out2, atol=1e-6)

    @pytest.mark.parametrize("length", [1, 5, 8, 19, 32])
    def test_per_block_scales_match_ref(self, length):
        """Frozen per-block grids (B=4, block_size=8): each row must
        dequantize through its own block's grid in kernel and reference."""
        q, _, _, k8, ks, v8, vs = _cache(2, 32, 16, seed=length, block_size=8)
        assert ks.shape == (2, 4, 16)
        got = np.asarray(quant.dequant_attention_decode(
            jnp.asarray(q), jnp.asarray(k8), jnp.asarray(ks),
            jnp.asarray(v8), jnp.asarray(vs), jnp.asarray(np.int32(length)),
            block_size=8))
        want = np.asarray(ref.attention_decode(
            jnp.asarray(q), jnp.asarray(k8), jnp.asarray(ks),
            jnp.asarray(v8), jnp.asarray(vs), length=length, block_size=8))
        np.testing.assert_allclose(got, want, atol=1e-5)

    def test_per_block_partial_tail_block(self):
        """T not a multiple of block_size: the last (short) block's grid
        still maps onto exactly its own rows."""
        q, _, _, k8, ks, v8, vs = _cache(2, 21, 16, seed=3, block_size=8)
        assert ks.shape == (2, 3, 16)
        got = np.asarray(quant.dequant_attention_decode(
            jnp.asarray(q), jnp.asarray(k8), jnp.asarray(ks),
            jnp.asarray(v8), jnp.asarray(vs), jnp.asarray(np.int32(21)),
            block_size=8))
        want = np.asarray(ref.attention_decode(
            jnp.asarray(q), jnp.asarray(k8), jnp.asarray(ks),
            jnp.asarray(v8), jnp.asarray(vs), length=21, block_size=8))
        np.testing.assert_allclose(got, want, atol=1e-5)

    if st is not None:

        @settings(max_examples=15, deadline=None)
        @given(h=st.integers(1, 4), t=st.integers(2, 24), d=st.integers(2, 48),
               seed=st.integers(0, 10_000))
        def test_matches_ref_hypothesis(self, h, t, d, seed):
            bs = 1 + seed % 8  # sweep block granularities too
            q, _, _, k8, ks, v8, vs = _cache(h, t, d, seed=seed, block_size=bs)
            length = 1 + seed % t
            got = np.asarray(quant.dequant_attention_decode(
                jnp.asarray(q), jnp.asarray(k8), jnp.asarray(ks),
                jnp.asarray(v8), jnp.asarray(vs),
                jnp.asarray(np.int32(length)), block_size=bs))
            want = np.asarray(ref.attention_decode(
                jnp.asarray(q), jnp.asarray(k8), jnp.asarray(ks),
                jnp.asarray(v8), jnp.asarray(vs), length=length,
                block_size=bs))
            np.testing.assert_allclose(got, want, atol=2e-5)


class TestErrorLaws:
    """The substrate-independent numbers the paper reports in §7.2/7.3."""

    def test_max_abs_error_00394(self):
        """U(-1,1) inputs: max error ≈ 1/(2·127) ≈ 0.00394 (Fig 4 left)."""
        rng = np.random.default_rng(0)
        k = rng.uniform(-1, 1, size=(4096, 256)).astype(np.float32)
        deq = np.asarray(ref.roundtrip(k))
        err = float(np.abs(k - deq).max())
        assert 0.0035 <= err <= 1.0 / (2 * 127) + 1e-6

    def test_identity_errors_are_zero(self):
        """Paper §7.5: every metric is 0 comparing a matrix to itself."""
        k = np.random.default_rng(1).normal(size=(64, 64)).astype(np.float32)
        assert float(ref.l2_error(k, k)) == 0.0
        assert float(ref.max_abs_error(k, k)) == 0.0
        q = np.random.default_rng(2).normal(size=(8, 64)).astype(np.float32)
        assert float(ref.attention_score_error(q, k, k)) == 0.0

    def test_l2_error_grows_with_size(self):
        rng = np.random.default_rng(3)
        errs = []
        for t in [256, 1024, 4096]:
            k = rng.uniform(-1, 1, size=(t, 128)).astype(np.float32)
            errs.append(float(ref.l2_error(k, np.asarray(ref.roundtrip(k)))))
        assert errs[0] < errs[1] < errs[2]

    def test_attention_error_scales_sqrt_d(self):
        """Fig 4 right: mean |q·k − q·k̂| grows ~√D with head dimension."""
        rng = np.random.default_rng(4)
        t, nq = 2048, 32
        errs = {}
        for d in [64, 256, 1024]:
            k = rng.uniform(-1, 1, size=(t, d)).astype(np.float32)
            q = rng.uniform(-1, 1, size=(nq, d)).astype(np.float32)
            k_hat = np.asarray(ref.roundtrip(k))
            errs[d] = float(ref.attention_score_error(q, k, k_hat))
        # Monotone growth and ratio ≈ sqrt(4)=2 per 4x D step (loose band).
        assert errs[64] < errs[256] < errs[1024]
        r1 = errs[256] / errs[64]
        r2 = errs[1024] / errs[256]
        assert 1.3 < r1 < 3.0 and 1.3 < r2 < 3.0

    def test_per_channel_beats_per_tensor(self):
        """The reason the paper uses per-channel scales: mixed-range columns."""
        rng = np.random.default_rng(5)
        k = rng.uniform(-1, 1, size=(512, 64)).astype(np.float32)
        k[:, 0] *= 100.0  # one hot column blows up a global scale
        # per-channel
        pc = np.asarray(ref.roundtrip(k))
        # per-tensor: single global scale
        s = np.abs(k).max() / 127.0
        pt = np.clip(np.round(k / s), -127, 127) * s
        err_pc = np.abs(k - pc)[:, 1:].max()  # error on the normal columns
        err_pt = np.abs(k - pt)[:, 1:].max()
        assert err_pc < err_pt / 10.0

    def test_per_block_beats_per_prompt_under_drift(self):
        """Why scales freeze per block (A12 ablation): when magnitudes
        drift across the sequence, a whole-prompt grid wastes resolution
        on early rows; per-block grids fit each block's own range."""
        rng = np.random.default_rng(6)
        t, d, bs = 64, 32, 8
        drift = (0.25 + 1.75 * np.arange(t) / t).astype(np.float32)
        k = rng.normal(size=(t, d)).astype(np.float32) * drift[:, None]

        s_all = np.asarray(ref.compute_scales(k))
        hat_all = np.asarray(ref.dequantize(
            np.asarray(ref.quantize(k, s_all)), s_all))

        hat_blk = np.zeros_like(k)
        for lo in range(0, t, bs):
            blk = k[lo:lo + bs]
            s = np.asarray(ref.compute_scales(blk))
            hat_blk[lo:lo + bs] = np.asarray(ref.dequantize(
                np.asarray(ref.quantize(blk, s)), s))

        assert np.abs(k - hat_blk).mean() < np.abs(k - hat_all).mean()
