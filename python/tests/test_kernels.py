"""Kernel-vs-oracle correctness: the CORE signal for layer 1.

Every Pallas variant must agree with the pure-jnp reference *exactly*
(both use round-half-away-from-zero; see ref.py's rounding note). The
paper's own validation suite (§7.5) allows ±1 between CPU and GPU; we
standardize the rounding mode instead and demand bit equality.
"""

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: property sweeps skip where absent
    given = settings = st = None

from compile.kernels import quant, ref

VARIANTS = sorted(quant.VARIANTS)


def _rand(t, d, seed=0, dist="uniform", scale=1.0):
    rng = np.random.default_rng(seed)
    if dist == "uniform":
        x = rng.uniform(-1.0, 1.0, size=(t, d))
    elif dist == "normal":
        x = rng.normal(0.0, 1.0, size=(t, d))
    elif dist == "outliers":
        x = rng.normal(0.0, 1.0, size=(t, d))
        n = max(1, t * d // 100)
        idx = rng.choice(t * d, size=n, replace=False)
        x.flat[idx] *= 100.0
    else:
        raise ValueError(dist)
    return (x * scale).astype(np.float32)


# ---------------------------------------------------------------------------
# Scales.
# ---------------------------------------------------------------------------


class TestScales:
    @pytest.mark.parametrize("t,d", [(64, 128), (128, 64), (100, 36), (1, 1)])
    def test_matches_ref(self, t, d):
        k = _rand(t, d, seed=t * 1000 + d)
        got = np.asarray(quant.compute_scales(jnp.asarray(k)))
        want = np.asarray(ref.compute_scales(k))
        np.testing.assert_allclose(got, want, rtol=0, atol=0)

    def test_known_values(self):
        # Column maxima 127 and 254 -> scales exactly 1 and 2.
        k = np.array([[127.0, -254.0], [-1.0, 2.0]], dtype=np.float32)
        got = np.asarray(quant.compute_scales(jnp.asarray(k)))
        np.testing.assert_array_equal(got, [1.0, 2.0])

    def test_zero_column_gives_zero_scale(self):
        k = np.zeros((16, 8), dtype=np.float32)
        k[:, 3] = 1.0
        got = np.asarray(quant.compute_scales(jnp.asarray(k)))
        assert got[0] == 0.0 and got[3] == pytest.approx(1.0 / 127.0)

    def test_accumulates_across_row_tiles(self):
        # Put the max in the last row strip to exercise the running-max
        # accumulation across the row grid dimension.
        k = np.full((4096, 16), 0.25, dtype=np.float32)
        k[-1, :] = 8.0
        got = np.asarray(quant.compute_scales(jnp.asarray(k), row_parts=16))
        np.testing.assert_allclose(got, np.full(16, 8.0 / 127.0))


# ---------------------------------------------------------------------------
# Quantize / dequantize variants.
# ---------------------------------------------------------------------------


class TestVariants:
    @pytest.mark.parametrize("variant", VARIANTS)
    @pytest.mark.parametrize("dist", ["uniform", "normal", "outliers"])
    def test_quantize_exact(self, variant, dist):
        k = _rand(96, 160, seed=7, dist=dist)
        s = np.asarray(ref.compute_scales(k))
        got = np.asarray(quant.VARIANTS[variant][0](jnp.asarray(k), jnp.asarray(s)))
        want = np.asarray(ref.quantize(k, s))
        assert got.dtype == np.int8
        np.testing.assert_array_equal(got, want)

    @pytest.mark.parametrize("variant", VARIANTS)
    def test_dequantize_exact(self, variant):
        k = _rand(64, 96, seed=3)
        s = np.asarray(ref.compute_scales(k))
        q8 = np.asarray(ref.quantize(k, s))
        got = np.asarray(quant.VARIANTS[variant][1](jnp.asarray(q8), jnp.asarray(s)))
        want = np.asarray(ref.dequantize(q8, s))
        np.testing.assert_array_equal(got, want)

    @pytest.mark.parametrize("variant", VARIANTS)
    def test_cross_variant_consistency(self, variant):
        """Paper §7.5: all GPU variants produce identical outputs."""
        k = _rand(80, 144, seed=11, dist="normal")
        s = np.asarray(ref.compute_scales(k))
        base = np.asarray(quant.quantize_naive(jnp.asarray(k), jnp.asarray(s)))
        got = np.asarray(quant.VARIANTS[variant][0](jnp.asarray(k), jnp.asarray(s)))
        np.testing.assert_array_equal(got, base)

    @pytest.mark.parametrize("variant", VARIANTS)
    def test_odd_shapes(self, variant):
        """Shapes not divisible by the preferred tiles (paper's 'requires D
        divisible by 4' caveat — our tile picker handles any shape)."""
        for t, d in [(1, 1), (3, 5), (17, 129), (257, 31)]:
            k = _rand(t, d, seed=t + d)
            s = np.asarray(ref.compute_scales(k))
            got = np.asarray(quant.VARIANTS[variant][0](jnp.asarray(k), jnp.asarray(s)))
            np.testing.assert_array_equal(got, np.asarray(ref.quantize(k, s)))


class TestFused:
    def test_matches_two_pass(self):
        k = _rand(128, 192, seed=5, dist="normal")
        kq, s = quant.quantize_fused(jnp.asarray(k))
        s_ref = np.asarray(ref.compute_scales(k))
        # XLA may compile /127 as *(1/127) inside the fused kernel: allow
        # 1-ulp scale wobble, and ±1 on quantized values sitting exactly on
        # a rounding boundary (same tolerance the paper's §7.5 suite uses).
        np.testing.assert_allclose(np.asarray(s), s_ref, rtol=1e-6)
        dq = np.asarray(kq).astype(np.int32) - np.asarray(ref.quantize(k, s_ref))
        assert np.abs(dq).max() <= 1
        assert (dq != 0).mean() < 0.01

    def test_odd_shape(self):
        k = _rand(33, 7, seed=9)
        kq, s = quant.quantize_fused(jnp.asarray(k))
        np.testing.assert_allclose(np.asarray(s), np.asarray(ref.compute_scales(k)))


# ---------------------------------------------------------------------------
# Edge cases — paper §7.5's degenerate inputs, plus a few it missed.
# ---------------------------------------------------------------------------


class TestEdgeCases:
    def test_all_zeros(self):
        k = np.zeros((8, 8), dtype=np.float32)
        kq, s = quant.quantize_fused(jnp.asarray(k))
        assert (np.asarray(kq) == 0).all() and (np.asarray(s) == 0).all()
        # Round-trip of all-zeros is exact.
        deq = np.asarray(ref.dequantize(np.asarray(kq), np.asarray(s)))
        assert (deq == 0).all()

    def test_all_ones(self):
        k = np.ones((8, 8), dtype=np.float32)
        kq, s = quant.quantize_fused(jnp.asarray(k))
        assert (np.asarray(kq) == 127).all()
        np.testing.assert_allclose(np.asarray(s), 1.0 / 127.0)

    def test_alternating_signs(self):
        k = np.fromfunction(lambda i, j: (-1.0) ** (i + j), (16, 16)).astype(np.float32)
        kq, _ = quant.quantize_fused(jnp.asarray(k))
        assert set(np.unique(np.asarray(kq))) == {-127, 127}

    def test_clamp_at_bounds(self):
        # Values exactly at ±max quantize to ±127, never overflow.
        k = np.array([[3.0, -3.0], [-3.0, 3.0]], dtype=np.float32)
        kq, s = quant.quantize_fused(jnp.asarray(k))
        assert np.abs(np.asarray(kq)).max() == 127

    def test_single_element(self):
        k = np.array([[0.5]], dtype=np.float32)
        kq, s = quant.quantize_fused(jnp.asarray(k))
        assert np.asarray(kq)[0, 0] == 127  # its own max -> full range
        np.testing.assert_allclose(np.asarray(s)[0], 0.5 / 127.0)

    def test_infinity_clamps(self):
        k = np.array([[np.inf, 1.0], [-np.inf, -1.0]], dtype=np.float32)
        s = np.array([1.0, 1.0], dtype=np.float32)
        got = np.asarray(quant.quantize_vectorized(jnp.asarray(k), jnp.asarray(s)))
        assert got[0, 0] == 127 and got[1, 0] == -127

    def test_half_away_rounding(self):
        # 0.5/1.0 rounds to 1 (away from zero), not 0 (banker's).
        k = np.array([[0.5, -0.5, 1.5, -1.5]], dtype=np.float32)
        s = np.ones(4, dtype=np.float32)
        got = np.asarray(quant.quantize_vectorized(jnp.asarray(k), jnp.asarray(s)))
        np.testing.assert_array_equal(got[0], [1, -1, 2, -2])


# ---------------------------------------------------------------------------
# Hypothesis sweeps: arbitrary shapes × distributions for every variant.
# ---------------------------------------------------------------------------


if st is not None:

    @st.composite
    def matrices(draw):
        t = draw(st.integers(min_value=1, max_value=96))
        d = draw(st.integers(min_value=1, max_value=96))
        seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
        dist = draw(st.sampled_from(["uniform", "normal", "outliers"]))
        scale = draw(st.sampled_from([1e-6, 1.0, 1e6]))
        return _rand(t, d, seed=seed, dist=dist, scale=scale)

    @settings(max_examples=25, deadline=None)
    @given(k=matrices(), variant=st.sampled_from(VARIANTS))
    def test_quantize_matches_ref_anywhere(k, variant):
        s = np.asarray(ref.compute_scales(k))
        got = np.asarray(quant.VARIANTS[variant][0](jnp.asarray(k), jnp.asarray(s)))
        np.testing.assert_array_equal(got, np.asarray(ref.quantize(k, s)))

    @settings(max_examples=25, deadline=None)
    @given(k=matrices())
    def test_roundtrip_error_bound(k):
        """|x - x̂| <= s_d / 2 per element — eq. (9)."""
        kq, s = quant.quantize_fused(jnp.asarray(k))
        deq = np.asarray(ref.dequantize(np.asarray(kq), np.asarray(s)))
        bound = np.asarray(s)[None, :] / 2.0
        err = np.abs(k - deq)
        # Elements beyond ±127·s are clamped; for abs-max scaling none
        # exceed it, so the bound holds everywhere (plus float slack).
        assert (err <= bound * (1 + 1e-5) + 1e-12).all()

    @settings(max_examples=15, deadline=None)
    @given(k=matrices())
    def test_scales_match_ref_anywhere(k):
        got = np.asarray(quant.compute_scales(jnp.asarray(k)))
        np.testing.assert_allclose(
            got, np.asarray(ref.compute_scales(k)), rtol=1e-6)
