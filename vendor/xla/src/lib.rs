//! Offline API-compatible stub of the `xla_extension` bindings.
//!
//! Mirrors the exact call surface `kvq`'s runtime layer uses. Host-side
//! types ([`Literal`], [`ArrayShape`], dtypes) are fully functional so
//! literal round-trips and validation logic work; device-side operations
//! ([`PjRtClient::cpu`], `compile`, `execute*`) return a descriptive
//! [`Error`] — callers treat this exactly like a machine without libxla,
//! and every PJRT-dependent test/bench in the repo already skips or
//! degrades gracefully on that path.

use std::fmt;

/// Stub error type (message only).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} unavailable: built against the offline `xla` stub crate \
         (vendor/xla); link the real xla_extension bindings for PJRT execution"
    ))
}

/// XLA element types (subset + room for growth; non-exhaustive like the
/// real bindings so downstream matches keep a wildcard arm).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[non_exhaustive]
pub enum ElementType {
    Pred,
    S8,
    S16,
    S32,
    S64,
    U8,
    U16,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
}

impl ElementType {
    /// Bytes per element.
    pub fn size(self) -> usize {
        match self {
            ElementType::Pred | ElementType::S8 | ElementType::U8 => 1,
            ElementType::S16 | ElementType::U16 | ElementType::F16 | ElementType::Bf16 => 2,
            ElementType::S32 | ElementType::U32 | ElementType::F32 => 4,
            ElementType::S64 | ElementType::U64 | ElementType::F64 => 8,
        }
    }
}

/// Host-native scalar types usable with buffers/literals.
pub trait NativeType: Copy {
    const TY: ElementType;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
}
impl NativeType for f64 {
    const TY: ElementType = ElementType::F64;
}
impl NativeType for i8 {
    const TY: ElementType = ElementType::S8;
}
impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
}
impl NativeType for i64 {
    const TY: ElementType = ElementType::S64;
}
impl NativeType for u8 {
    const TY: ElementType = ElementType::U8;
}

/// Shape of an array literal: element type + dimensions.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn ty(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().map(|&d| d as usize).product()
    }
}

#[derive(Debug, Clone)]
enum Repr {
    Array { shape: ArrayShape, data: Vec<u8> },
    Tuple(Vec<Literal>),
}

/// A host-side literal: fully functional in the stub.
#[derive(Debug, Clone)]
pub struct Literal(Repr);

impl Literal {
    /// Build an array literal from raw bytes (copies once).
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let n: usize = dims.iter().product();
        if data.len() != n * ty.size() {
            return Err(Error(format!(
                "literal byte size {} != {} elements x {} bytes",
                data.len(),
                n,
                ty.size()
            )));
        }
        Ok(Literal(Repr::Array {
            shape: ArrayShape { ty, dims: dims.iter().map(|&d| d as i64).collect() },
            data: data.to_vec(),
        }))
    }

    /// Wrap literals into a tuple (mirrors return_tuple=True outputs).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal(Repr::Tuple(parts))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match &self.0 {
            Repr::Array { shape, .. } => Ok(shape.clone()),
            Repr::Tuple(_) => Err(Error("array_shape of tuple literal".into())),
        }
    }

    /// Copy the payload into a typed host slice.
    pub fn copy_raw_to<T: NativeType>(&self, dst: &mut [T]) -> Result<()> {
        let Repr::Array { shape, data } = &self.0 else {
            return Err(Error("copy_raw_to on tuple literal".into()));
        };
        if shape.ty() != T::TY {
            return Err(Error(format!("dtype mismatch: {:?} vs {:?}", shape.ty(), T::TY)));
        }
        if dst.len() * std::mem::size_of::<T>() != data.len() {
            return Err(Error(format!(
                "copy_raw_to size mismatch: {} bytes into {} elements",
                data.len(),
                dst.len()
            )));
        }
        // SAFETY: lengths checked above; T is a plain scalar.
        unsafe {
            std::ptr::copy_nonoverlapping(data.as_ptr(), dst.as_mut_ptr() as *mut u8, data.len());
        }
        Ok(())
    }

    /// Split a tuple literal into its parts.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        match std::mem::replace(&mut self.0, Repr::Tuple(Vec::new())) {
            Repr::Tuple(parts) => Ok(parts),
            arr @ Repr::Array { .. } => {
                // Single-output executables may return a bare array.
                self.0 = Repr::Tuple(Vec::new());
                Ok(vec![Literal(arr)])
            }
        }
    }
}

/// Device buffer handle (stub: cannot be materialized).
#[derive(Debug)]
pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A device placement handle (stub).
#[derive(Debug)]
pub struct PjRtDevice {}

/// A compiled executable (stub: cannot be constructed via compile()).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }

    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// PJRT client (stub: construction reports PJRT as unavailable).
#[derive(Debug)]
pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

/// Parsed HLO module (stub: existence-checked, not parsed).
#[derive(Debug)]
pub struct HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        std::fs::metadata(path).map_err(|e| Error(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto {})
    }
}

/// An XLA computation handle (stub).
#[derive(Debug)]
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data = [1.0f32, -2.5, 3.25, 0.0];
        let bytes: &[u8] =
            unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, 16) };
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 2], bytes).unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(shape.dims(), &[2, 2]);
        let mut out = [0.0f32; 4];
        lit.copy_raw_to(&mut out).unwrap();
        assert_eq!(out, data);
    }

    #[test]
    fn literal_size_validation() {
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &[0u8; 4])
            .is_err());
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::S8, &[2], &[1u8, 2]).unwrap();
        let mut wrong = [0.0f32; 2];
        assert!(lit.copy_raw_to(&mut wrong).is_err());
    }

    #[test]
    fn tuple_decomposes() {
        let a = Literal::create_from_shape_and_untyped_data(ElementType::S8, &[1], &[7u8]).unwrap();
        let mut t = Literal::tuple(vec![a.clone(), a]);
        let parts = t.decompose_tuple().unwrap();
        assert_eq!(parts.len(), 2);
    }

    #[test]
    fn device_paths_report_stub() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("stub"));
    }
}
