//! Minimal offline substitute for the `anyhow` crate.
//!
//! Implements the subset the kvq stack uses: [`Error`] (context chain,
//! `{:#}` alternate formatting), [`Result`], the [`Context`] extension
//! trait on `Result` and `Option`, and the `anyhow!` / `bail!` macros.
//! No downcasting, no backtraces.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error: a chain of context frames, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Prepend a context frame (the `Context` trait calls this).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context frames, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    fn full(&self) -> String {
        self.chain.join(": ")
    }
}

impl fmt::Display for Error {
    /// `{}` prints the outermost message; `{:#}` the full `a: b: c` chain.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.full())
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.full())
    }
}

// Like real anyhow: Error deliberately does NOT implement std::error::Error,
// which is what makes this blanket From legal.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Conversion into [`Error`] for both std errors and `Error` itself —
/// the (sealed) trick that lets `.context()` apply to `anyhow::Result`
/// as well as `Result<_, E: std::error::Error>`, like real anyhow.
mod ext {
    use super::{Error, StdError};

    pub trait IntoError {
        fn into_err(self) -> Error;
    }

    impl<E: StdError + Send + Sync + 'static> IntoError for E {
        fn into_err(self) -> Error {
            Error::from(self)
        }
    }

    impl IntoError for Error {
        fn into_err(self) -> Error {
            self
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: ext::IntoError,
{
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_err().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_err().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// `return Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// `if !cond { bail!(..) }`.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Err::<(), _>(io_err()).context("reading x").unwrap_err();
        assert_eq!(format!("{e}"), "reading x");
        assert_eq!(format!("{e:#}"), "reading x: gone");
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
        let r2: Result<()> = Err(anyhow!("deep"));
        let e2 = r2.with_context(|| "mid").unwrap_err();
        let e2 = Err::<(), _>(e2).context("top").unwrap_err();
        assert_eq!(format!("{e2:#}"), "top: mid: deep");
    }

    #[test]
    fn with_context_and_option() {
        let e = None::<u32>.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
        let ok = Some(3u32).context("unused").unwrap();
        assert_eq!(ok, 3);
    }

    #[test]
    fn macros() {
        let x = 4;
        let e = anyhow!("bad value {x}");
        assert_eq!(format!("{e}"), "bad value 4");
        fn f() -> Result<()> {
            bail!("nope {}", 1)
        }
        assert_eq!(format!("{:#}", f().unwrap_err()), "nope 1");
        fn g(ok: bool) -> Result<u8> {
            ensure!(ok, "not ok");
            Ok(1)
        }
        assert!(g(true).is_ok());
        assert!(g(false).is_err());
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/here/ever")?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
